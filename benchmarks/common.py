"""Shared benchmark machinery: scaled-down paper experiment settings.

The paper trains MLP/CNN on MNIST/CIFAR-10 for K=500-2000 rounds; offline
CPU benches reproduce the *qualitative* claims at reduced scale (documented
per bench).  Every bench returns rows (name, us_per_call, derived-metrics).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import MLP_MNIST, ClassifierConfig
from repro.core import (FedAvg, FedDeper, FedProx, Scaffold, SimConfig,
                        init_sim_state, make_global_eval, make_personal_eval,
                        make_round_fn, run_rounds)
from repro.data import make_federated_classification
from repro.models import classifier_loss, init_classifier

# calibrated so convergence-rate differences between strategies are
# visible before everything reaches the optimum (see EXPERIMENTS.md §Repro)
DATA_KW = dict(noise=4.0, per_client=256, split="shards",
               shards_per_client=2)


def build_task(cfg: ClassifierConfig, n_clients: int, seed: int = 0):
    ds = make_federated_classification(
        input_shape=cfg.input_shape, n_clients=n_clients, seed=seed,
        **DATA_KW)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    test = {k: jnp.asarray(v) for k, v in ds.test.items()}
    personal = {k: jnp.asarray(v) for k, v in ds.personal_test.items()}
    # flattened train split: the paper's "global training loss" = f(x)
    train_flat = {k: v.reshape((-1,) + v.shape[2:]) for k, v in data.items()}

    def apply_loss(p, b):
        return classifier_loss(cfg, p, b)

    def grad_fn(p, mb):
        (l, m), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
        return l, g

    return dict(ds=ds, data=data, test=test, personal=personal,
                train_flat=train_flat, apply_loss=apply_loss,
                grad_fn=grad_fn)


class SyntheticClientData:
    """On-demand federated classification rows: the virtual round
    executor's data source for populations too large to materialize as
    dense ``(n_clients, per_client, ...)`` arrays.  ``take(idx)``
    synthesizes the requested clients' rows (class-prototype Gaussians
    with a skewed per-client label mixture, same family as
    ``make_federated_classification``) deterministically from
    ``np.random.SeedSequence([seed, client_id])`` -- a client's rows
    are identical every time they are drawn, and no population-sized
    array ever exists, so n=100k costs the same host memory as n=10."""

    def __init__(self, *, input_shape=(784,), num_classes=10,
                 n_clients=10, per_client=256, noise=4.0, seed=0):
        self.input_shape = tuple(input_shape)
        self.num_classes = int(num_classes)
        self.n_clients = int(n_clients)
        self.n_rows = int(per_client)
        self.noise = float(noise)
        self.seed = int(seed)
        # prototypes are population-global; the population-sized part
        # (per-client rows) stays virtual
        prng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.n_clients]))
        self._protos = prng.normal(
            0, 1.0, size=(self.num_classes,) + self.input_shape
        ).astype(np.float32)

    def _client_rows(self, c: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(c)]))
        mix = rng.dirichlet([0.3] * self.num_classes)
        y = rng.choice(self.num_classes, size=self.n_rows,
                       p=mix).astype(np.int32)
        x = (self._protos[y] + rng.normal(
            0, self.noise,
            size=(self.n_rows,) + self.input_shape)).astype(np.float32)
        return x, y

    def take(self, idx):
        cols = [self._client_rows(c) for c in np.asarray(idx).ravel()]
        return {"x": np.stack([x for x, _ in cols]),
                "y": np.stack([y for _, y in cols])}


def run_strategy(cfg, task, strategy, *, n, m, tau, rounds, batch=32,
                 seed=0, eval_every=10**9, personal=False):
    sim = SimConfig(n_clients=n, m_sampled=m, tau=tau, batch_size=batch,
                    seed=seed)
    x0 = init_classifier(cfg, jax.random.PRNGKey(42))
    state = init_sim_state(sim, strategy, x0)
    rf = make_round_fn(sim, strategy, task["grad_fn"], task["data"])
    test_eval = make_global_eval(task["apply_loss"], task["test"])
    train_eval = make_global_eval(task["apply_loss"], task["train_flat"])

    def eval_fn(state):
        out = test_eval(state)
        tr = train_eval(state)
        out["global_train_loss"] = tr["test_loss"]
        return out
    if personal:
        pe = make_personal_eval(task["apply_loss"], task["personal"])
        base_eval = eval_fn

        def eval_fn(state):  # noqa: F811
            out = base_eval(state)
            out.update(pe(state))
            return out

    t0 = time.time()
    state, hist = run_rounds(state, rf, rounds, eval_fn=eval_fn,
                             eval_every=min(eval_every, rounds))
    dt = time.time() - t0
    us_per_round = 1e6 * dt / rounds
    return state, hist, us_per_round


def strategies_for(eta=0.05, rho=0.03, lam=0.5):
    return {
        "feddeper": FedDeper(eta=eta, rho=rho, lam=lam),
        "fedavg": FedAvg(eta=eta),
        "fedprox": FedProx(eta=eta, mu=1.0),
        "scaffold": Scaffold(eta=eta),
    }


def csv_row(name: str, us: float, derived: Dict) -> str:
    dstr = ";".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in derived.items())
    return f"{name},{us:.1f},{dstr}"
