"""Micro-benchmarks for the Pallas kernel reference paths on CPU.

Wall-times here are CPU interpret/XLA numbers -- NOT TPU perf; the TPU
story lives in the roofline analysis.  These rows track relative cost of
the fused deper_update vs the unfused tree-map path (the kernel's reason
to exist: 7 vs ~10 HBM passes) and the chunked-attention ref throughput.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row


def _time(f, *args, iters=5):
    f(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        r = f(*args)
    jax.block_until_ready(r)
    return 1e6 * (time.time() - t0) / iters


def deper_update_bench(quick=True) -> List[str]:
    from repro.kernels import ref
    n = 1 << 20 if quick else 1 << 24
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    y, v, x, gy, gv = (jax.random.normal(k, (n,)) for k in ks)

    @jax.jit
    def unfused(y, v, x, gy, gv):
        return ref.deper_update_ref(y, v, x, gy, gv, eta=0.01, rho=0.003)

    us_unfused = _time(unfused, y, v, x, gy, gv)
    return [csv_row("deper_update_unfused_1M", us_unfused,
                    {"elements": n})]


def attention_bench(quick=True) -> List[str]:
    from repro.models.attention import chunked_attention
    B, S, H, K, D = 1, 1024 if quick else 4096, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    pos = jnp.arange(S)

    @jax.jit
    def run(q, k, v):
        return chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                                 causal=True)

    us = _time(run, q, k, v, iters=3)
    flops = 4.0 * B * H * S * S * D
    return [csv_row(f"chunked_attention_S{S}", us,
                    {"gflops_per_s": flops / us / 1e3})]


def moe_bench(quick=True) -> List[str]:
    import dataclasses
    from repro.configs import get_config
    from repro.models.moe import apply_moe, init_moe
    cfg = get_config("granite-moe-3b-a800m")
    cfg = dataclasses.replace(cfg, d_model=256, moe_d_ff=128,
                              num_experts=8, experts_per_token=2)
    params = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, cfg.d_model))

    @jax.jit
    def run(x):
        out, aux = apply_moe(cfg, params, x)
        return out, aux.dropped_frac

    out, dropped = run(x)
    us = _time(lambda x: run(x)[0], x, iters=3)
    return [csv_row("moe_dispatch_512tok_8e", us,
                    {"dropped_frac": float(dropped)})]
