"""Serving-tier benchmark: the tracked ``BENCH_serve.json`` numbers.

Companion to ``round_engine.py``'s training bench: every run rewrites
``BENCH_serve.json`` at the repo root so each PR leaves a serving perf
trajectory next to the training one.  Schema (validated by
``validate_serve_bench``; CI runs a smoke subset through it and through
``round_engine.check_speedups`` -- the gate is generic over
``config.speedup_vs_*`` ratios and ``peak_bytes`` ceilings):

    { bench_name: {
        "tokens_per_s": float,     # decoded tokens / wall second
        "p50_ms": float,           # latency p50 (block rows: per decode
        "p99_ms": float,           #   block; simulate: per request)
        "peak_bytes": int,         # decode-block executable's static
                                   #   temp+output allocation plan
        "config": { ... } } }

Rows:

  * ``block`` -- the ServeEngine's jitted ``lax.scan`` decode block
    (one dispatch + one host sync per ``block_tokens`` steps).  Carries
    ``config.speedup_vs_loop``, measured INTERLEAVED with the loop row
    so machine-speed drift cancels out of the tracked ratio.
  * ``loop``  -- the pre-serve-tier baseline: the same engine math with
    ``block_tokens=1``, i.e. one dispatch and one device->host token
    fetch per decoded token (what ``launch/serve.py`` did before the
    redesign).
  * ``simulate`` -- the continuous-batching request simulator: mixed
    prompt lengths, slot reuse, burst arrivals; p50/p99 are REQUEST
    latencies.
  * ``q8`` -- the block row on int8-served weights
    (``serve.make_weight_source("q8")``): tracks that the quantized
    source keeps the same decode throughput shape and records its
    resident footprint.

``peak_bytes`` reuses ``round_engine._compiled_peak`` on the engine's
block step -- THE one definition of peak, shared with the training
bench.  AOT-lowering the block also seeds nothing: the engine's
compile-once contract (``block_compile_count() == 1``) still holds over
the timed windows, which the bench asserts.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from benchmarks.round_engine import _compiled_peak, _sds

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serve.json"

ARCH = "llama3.2-3b"

# the quick operating point: CPU-sized reduced config, small windows
QUICK = dict(slots=4, max_len=128, block_tokens=16, prompt_len=8,
             window_blocks=2, reps=3, requests=8, gen_tokens=24)
FULL = dict(slots=8, max_len=256, block_tokens=32, prompt_len=16,
            window_blocks=4, reps=5, requests=16, gen_tokens=64)

_ENTRY_KEYS = {"tokens_per_s", "p50_ms", "p99_ms", "peak_bytes", "config"}
_CONFIG_REQUIRED = {"arch", "slots", "max_len", "block_tokens"}


def validate_serve_bench(obj) -> None:
    """Raise ValueError unless ``obj`` matches the BENCH_serve schema.
    Unknown entry keys are rejected; rows served from a quantized
    weight source (``config.weights`` head q8/fp8) must also record
    ``config.resident_bytes`` -- the footprint claim is the row's
    point."""
    if not isinstance(obj, dict) or not obj:
        raise ValueError("serve bench json must be a non-empty dict")
    for name, entry in obj.items():
        if not isinstance(name, str):
            raise ValueError(f"bench name {name!r} is not a string")
        if not isinstance(entry, dict):
            raise ValueError(f"{name}: entry must be a dict")
        missing = _ENTRY_KEYS - set(entry)
        if missing:
            raise ValueError(f"{name}: missing keys {sorted(missing)}")
        unknown = set(entry) - _ENTRY_KEYS
        if unknown:
            raise ValueError(f"{name}: unknown keys {sorted(unknown)} "
                             f"(schema allows {sorted(_ENTRY_KEYS)})")
        tps = entry["tokens_per_s"]
        if not isinstance(tps, (int, float)) or isinstance(tps, bool) \
                or tps <= 0:
            raise ValueError(f"{name}: tokens_per_s must be positive")
        for key in ("p50_ms", "p99_ms"):
            v = entry[key]
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or v < 0:
                raise ValueError(f"{name}: {key} must be a non-negative "
                                 f"number (got {v!r})")
        if entry["p99_ms"] < entry["p50_ms"]:
            raise ValueError(f"{name}: p99_ms < p50_ms "
                             f"({entry['p99_ms']} < {entry['p50_ms']})")
        pb = entry["peak_bytes"]
        if not isinstance(pb, int) or isinstance(pb, bool) or pb <= 0:
            raise ValueError(f"{name}: peak_bytes must be a positive int "
                             f"(got {pb!r})")
        cfg = entry["config"]
        if not isinstance(cfg, dict):
            raise ValueError(f"{name}: config must be a dict")
        miss = _CONFIG_REQUIRED - set(cfg)
        if miss:
            raise ValueError(f"{name}: config missing {sorted(miss)}")
        head = str(cfg.get("weights", "")).split(":", 1)[0]
        if head in ("q8", "fp8"):
            rb = cfg.get("resident_bytes")
            if not isinstance(rb, int) or isinstance(rb, bool) or rb <= 0:
                raise ValueError(
                    f"{name}: quantized-weight rows must record "
                    f"config.resident_bytes as a positive int (got "
                    f"{rb!r})")


def _build_engine(cfg, params, scale, block_tokens):
    from repro.serve import ServeEngine
    return ServeEngine(cfg, params, slots=scale["slots"],
                       max_len=scale["max_len"],
                       block_tokens=block_tokens)


def _prompts(cfg, scale, seed=0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xB37C]))
    return [rng.integers(0, cfg.vocab_size, scale["prompt_len"],
                         dtype=np.int64).astype(np.int32)
            for _ in range(scale["slots"])]


def _readmit(engine, prompts):
    """Reset every slot to post-prefill state (re-admission overwrites
    the full slot state, so timed windows always start from the same
    lens)."""
    for i, p in enumerate(prompts):
        engine.admit(i, p)


def _window(engine, n_blocks):
    """Time ``n_blocks`` decode blocks; returns (total_s, [block_s])."""
    lat = []
    t0 = time.perf_counter()
    for _ in range(n_blocks):
        tb = time.perf_counter()
        engine.run_block()
        lat.append(time.perf_counter() - tb)
    return time.perf_counter() - t0, lat


def _block_peak(engine):
    """peak_bytes of the engine's decode-block executable (same
    ``_compiled_peak`` definition as the training bench)."""
    s = engine.slots
    args = (_sds(engine.params), _sds(engine.cache),
            jax.ShapeDtypeStruct((s, 1), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.bool_))
    _, peak = _compiled_peak(engine._block, *args)
    return peak


def _timed_entry(scale, block_tokens, best_s, lats, n_blocks, peak,
                 extra_cfg=None):
    tokens = n_blocks * block_tokens * scale["slots"]
    lat_ms = np.asarray(lats) * 1e3
    cfg = {"arch": ARCH, "slots": scale["slots"],
           "max_len": scale["max_len"], "block_tokens": block_tokens,
           "prompt_len": scale["prompt_len"],
           "window_blocks": n_blocks}
    cfg.update(extra_cfg or {})
    return {
        "tokens_per_s": round(tokens / max(best_s, 1e-9), 1),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 4),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 4),
        "peak_bytes": peak,
        "config": cfg,
    }


def serve_rows(quick: bool = True, *,
               include: Optional[Iterable[str]] = None,
               reps: Optional[int] = None,
               out_path: Optional[Path] = BENCH_PATH) -> List[str]:
    """Run the serving benches, rewrite BENCH_serve.json (unless
    ``out_path=None``), return CSV rows.  ``include`` limits to a subset
    (CI smoke refreshes its rows in place)."""
    from repro.configs import get_config
    from repro.models import init_model
    from repro.serve import SimConfig, make_weight_source, simulate

    scale = QUICK if quick else FULL
    reps = reps if reps is not None else scale["reps"]
    names = set(include) if include is not None else \
        {"block", "loop", "simulate", "q8"}
    # the ratio needs both sides: a smoke asking for the block row
    # implicitly prices the loop baseline too
    if "block" in names:
        names.add("loop")

    cfg = get_config(ARCH).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, scale)
    nb = scale["window_blocks"]
    results: Dict[str, Dict] = {}

    block_eng = loop_eng = None
    if "block" in names or "loop" in names:
        block_eng = _build_engine(cfg, params, scale,
                                  scale["block_tokens"])
        loop_eng = _build_engine(cfg, params, scale, 1)
        # warm every compile the timed windows hit (prefill bucket,
        # admit, block step), then interleave the two sides' rep
        # windows so machine-speed drift cancels out of the ratio
        for eng in (block_eng, loop_eng):
            _readmit(eng, prompts)
            eng.run_block()
        best_b = best_l = float("inf")
        lats_b: List[float] = []
        lats_l: List[float] = []
        nb_loop = nb * scale["block_tokens"]  # same token budget
        for _ in range(reps):
            _readmit(block_eng, prompts)
            dt, lat = _window(block_eng, nb)
            best_b = min(best_b, dt)
            lats_b.extend(lat)
            _readmit(loop_eng, prompts)
            dt, lat = _window(loop_eng, nb_loop)
            best_l = min(best_l, dt)
            lats_l.extend(lat)
        assert block_eng.block_compile_count() == 1, \
            "decode block retraced during the timed windows"
        speedup = round(best_l / max(best_b, 1e-9), 3)
        if "block" in names:
            results["block"] = _timed_entry(
                scale, scale["block_tokens"], best_b, lats_b, nb,
                _block_peak(block_eng),
                {"weights": "init:0", "speedup_vs_loop": speedup})
        if "loop" in names:
            results["loop"] = _timed_entry(
                scale, 1, best_l, lats_l, nb_loop,
                _block_peak(loop_eng), {"weights": "init:0"})

    if "simulate" in names:
        eng = block_eng or _build_engine(cfg, params, scale,
                                         scale["block_tokens"])
        for i in range(eng.slots):  # timed windows left slots admitted
            eng.release(i)
        sim = SimConfig(requests=scale["requests"],
                        prompt_lens=(4, 8, 12, 16),
                        gen_tokens=scale["gen_tokens"], delay=0.0,
                        seed=0)
        m = simulate(eng, sim)
        results["simulate"] = {
            "tokens_per_s": round(m["tokens_per_s"], 1),
            "p50_ms": round(m["p50_ms"], 4),
            "p99_ms": round(m["p99_ms"], 4),
            "peak_bytes": _block_peak(eng),
            "config": {"arch": ARCH, "slots": eng.slots,
                       "max_len": eng.max_len,
                       "block_tokens": eng.block_tokens,
                       "weights": "init:0",
                       "requests": scale["requests"],
                       "gen_tokens": scale["gen_tokens"],
                       "prompt_lens": "4,8,12,16"},
        }

    if "q8" in names:
        source = make_weight_source("q8")
        q_eng = _build_engine(cfg, source.load(cfg), scale,
                              scale["block_tokens"])
        _readmit(q_eng, prompts)
        q_eng.run_block()  # warm
        best_q = float("inf")
        lats_q: List[float] = []
        for _ in range(reps):
            _readmit(q_eng, prompts)
            dt, lat = _window(q_eng, nb)
            best_q = min(best_q, dt)
            lats_q.extend(lat)
        results["q8"] = _timed_entry(
            scale, scale["block_tokens"], best_q, lats_q, nb,
            _block_peak(q_eng),
            {"weights": source.name,
             "resident_bytes": source.resident_bytes(cfg)})

    rows = []
    for name, entry in results.items():
        tokens = entry["tokens_per_s"]
        us_per_token = 1e6 / max(tokens, 1e-9)
        derived = {"tokens_per_s": tokens, "p50_ms": entry["p50_ms"],
                   "p99_ms": entry["p99_ms"]}
        if "speedup_vs_loop" in entry["config"]:
            derived["speedup_vs_loop"] = \
                entry["config"]["speedup_vs_loop"]
        if "resident_bytes" in entry["config"]:
            derived["resident_bytes"] = \
                entry["config"]["resident_bytes"]
        rows.append(csv_row(f"serve/{name}", us_per_token, derived))

    if out_path is not None and results:
        written = results
        if include is not None and out_path.exists():
            # subset runs (CI smoke) refresh their rows in place
            try:
                written = json.loads(out_path.read_text())
            except json.JSONDecodeError:
                written = {}
            written.update(results)
        validate_serve_bench(written)
        out_path.write_text(json.dumps(written, indent=2, sort_keys=True)
                            + "\n")
    return rows


if __name__ == "__main__":
    for row in serve_rows():
        print(row)
