"""Paper-figure reproductions (scaled for CPU; qualitative claims C1-C5).

fig1   -- heterogeneity: fixed K*tau, growing tau degrades non-iid FedAvg.
fig3   -- FedDeper hyper-parameters: rho sweep, lambda sweep, tau effect.
fig4_6 -- convergence-rate comparison vs baselines (moderate + massive).
fig7   -- personalized vs global model local performance (Thm 2 check).
table1 -- final test accuracy under fixed K (incl. FedDeper* tau/2).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import (build_task, csv_row, run_strategy,
                               strategies_for)
from repro.configs.paper_models import CNN_MNIST, MLP_MNIST
from repro.core import FedAvg, FedDeper
from repro.data import make_federated_classification


def fig1_heterogeneity(quick=True) -> List[str]:
    """C1: with K*tau fixed, training loss after the budget grows with tau
    on non-iid data (and doesn't on iid)."""
    rows = []
    cfg = MLP_MNIST
    total = 240 if quick else 2000
    for split, alpha_name in (("shards", "noniid"), ("dirichlet", "iid-ish")):
        task = build_task(cfg, n_clients=10)
        if split == "dirichlet":  # alpha -> inf == iid; emulate with high a
            from repro.data import make_federated_classification
            import jax.numpy as jnp
            ds = make_federated_classification(
                n_clients=10, per_client=256, split="dirichlet", alpha=100.0,
                noise=4.0)
            task["data"] = {k: jnp.asarray(v) for k, v in ds.train.items()}
        losses = {}
        for tau in (2, 8, 24):
            k_rounds = total // tau
            _, hist, us = run_strategy(cfg, task, FedAvg(eta=0.05), n=10,
                                       m=5, tau=tau, rounds=k_rounds)
            losses[f"tau{tau}"] = float(np.mean(
                [h["local_loss"] for h in hist[-3:]]))
        mono = losses["tau2"] <= losses["tau8"] <= losses["tau24"]
        rows.append(csv_row(f"fig1_{alpha_name}", us,
                            {**losses, "monotone_degradation": int(mono)}))
    return rows


def fig3_hyperparams(quick=True) -> List[str]:
    rows = []
    cfg = MLP_MNIST
    task = build_task(cfg, n_clients=10)
    rounds = 40 if quick else 500
    # (a) rho sweep -- best performance at moderate rho (same order as eta)
    for rho in (0.0, 0.01, 0.05, 0.2):
        _, hist, us = run_strategy(
            cfg, task, FedDeper(eta=0.05, rho=rho, lam=0.5), n=10, m=5,
            tau=10, rounds=rounds)
        rows.append(csv_row(f"fig3a_rho{rho}", us,
                            {"final_loss": hist[-1]["local_loss"]}))
    # (b) lambda sweep in [1/2, 1]
    for lam in (0.5, 0.75, 1.0):
        _, hist, us = run_strategy(
            cfg, task, FedDeper(eta=0.05, rho=0.03, lam=lam), n=10, m=5,
            tau=10, rounds=rounds)
        rows.append(csv_row(f"fig3b_lam{lam}", us,
                            {"final_loss": hist[-1]["local_loss"]}))
    # (c) tau effect -- more local steps per round helps at fixed K
    for tau in (2, 5, 10):
        _, hist, us = run_strategy(
            cfg, task, FedDeper(eta=0.05, rho=0.03, lam=0.5), n=10, m=5,
            tau=tau, rounds=rounds)
        rows.append(csv_row(f"fig3c_tau{tau}", us,
                            {"final_loss": hist[-1]["local_loss"]}))
    return rows


def fig4_6_convergence(quick=True) -> List[str]:
    """C3: FedDeper lowest train loss per round; on par with SCAFFOLD at
    half its communication."""
    rows = []
    scenarios = [("fig4_moderate_mlp", MLP_MNIST, 10, 5),
                 ("fig5_massive_mlp", MLP_MNIST, 50, 5)]
    if not quick:
        scenarios += [("fig6_massive_cnn", CNN_MNIST, 100, 5)]
    rounds = 50 if quick else 500
    for name, cfg, n, m in scenarios:
        task = build_task(cfg, n_clients=n)
        finals = {}
        us = 0.0
        # the paper tunes rho down for the massive/low-sampling scenario
        # (Fig. 7 caption: rho=0.03 at n=10, 0.005 at n=100)
        rho = 0.03 if n <= 10 else 0.005
        for sname, strat in strategies_for(rho=rho).items():
            _, hist, us = run_strategy(cfg, task, strat, n=n, m=m, tau=10,
                                       rounds=rounds,
                                       eval_every=rounds // 2)
            mid = next(h for h in hist if "global_train_loss" in h)
            finals[f"{sname}_mid"] = float(mid["global_train_loss"])
            finals[sname] = float(hist[-1]["global_train_loss"])
            finals[f"{sname}_acc"] = float(hist[-1]["test_acc"])
        # FedDeper* (tau/2): compute cost aligned with single-model runs
        _, hist, _ = run_strategy(
            cfg, task, strategies_for(rho=rho)["feddeper"], n=n, m=m, tau=5,
            rounds=rounds, eval_every=rounds)
        finals["feddeper_star"] = float(hist[-1]["global_train_loss"])
        finals["feddeper_wins_fedavg"] = int(
            finals["feddeper"] <= finals["fedavg"] + 1e-6)
        rows.append(csv_row(name, us, finals))
    return rows


def fig7_personalization(quick=True) -> List[str]:
    """C5 / Thm 2: personalized models converge around the global model."""
    rows = []
    cfg = MLP_MNIST
    task = build_task(cfg, n_clients=10)
    rounds = 40 if quick else 500
    _, hist, us = run_strategy(
        cfg, task, FedDeper(eta=0.05, rho=0.03, lam=0.5), n=10, m=5,
        tau=10, rounds=rounds, eval_every=rounds, personal=True)
    h = hist[-1]
    rows.append(csv_row("fig7_feddeper", us, {
        "pm_acc": h["pm_acc"], "gm_local_acc": h["gm_local_acc"],
        "pm_tracks_gm": int(abs(h["pm_acc"] - h["gm_local_acc"]) < 0.15),
    }))
    _, hist, us = run_strategy(cfg, task, FedAvg(eta=0.05), n=10, m=5,
                               tau=10, rounds=rounds, eval_every=rounds,
                               personal=True)
    h = hist[-1]
    rows.append(csv_row("fig7_fedavg", us, {
        "pm_acc": h["pm_acc"], "gm_local_acc": h["gm_local_acc"]}))
    return rows


def table1_accuracy(quick=True) -> List[str]:
    """C4: final test accuracy under fixed K; FedDeper & FedDeper* lead."""
    rows = []
    cfg = MLP_MNIST if quick else CNN_MNIST
    n, rounds = (10, 60) if quick else (100, 500)
    task = build_task(cfg, n_clients=n)
    rho = 0.03 if n <= 10 else 0.005
    for m in (5, 10):
        finals = {}
        us = 0.0
        for sname, strat in strategies_for(rho=rho).items():
            _, hist, us = run_strategy(cfg, task, strat, n=n, m=m, tau=10,
                                       rounds=rounds, eval_every=rounds)
            finals[sname] = float(hist[-1]["test_acc"])
        # FedDeper*: half the local steps (compute-aligned with baselines)
        _, hist, us = run_strategy(cfg, task,
                                   FedDeper(eta=0.05, rho=rho, lam=0.5),
                                   n=n, m=m, tau=5, rounds=rounds,
                                   eval_every=rounds)
        finals["feddeper_star"] = float(hist[-1]["test_acc"])
        rows.append(csv_row(f"table1_m{m}", us, finals))
    return rows
