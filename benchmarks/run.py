"""Benchmark harness: one bench per paper table/figure + roofline table.

Prints ``name,us_per_call,derived`` CSV.  --full runs paper-scale settings
(hours on CPU); default is the quick qualitative pass.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (async_bench, kernel_bench, paper_figs,
                            roofline, round_engine, serve_bench)
    benches = {
        "async": lambda: async_bench.async_vs_sync(quick),
        "round_engine": lambda: round_engine.round_engine_rows(quick),
        "serve": lambda: serve_bench.serve_rows(quick),
        "fig1": lambda: paper_figs.fig1_heterogeneity(quick),
        "fig3": lambda: paper_figs.fig3_hyperparams(quick),
        "fig4_6": lambda: paper_figs.fig4_6_convergence(quick),
        "fig7": lambda: paper_figs.fig7_personalization(quick),
        "table1": lambda: paper_figs.table1_accuracy(quick),
        "kernels": lambda: (kernel_bench.deper_update_bench(quick)
                            + kernel_bench.attention_bench(quick)
                            + kernel_bench.moe_bench(quick)),
        "roofline": roofline.rows,
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            for row in fn():
                print(row, flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,status=FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
