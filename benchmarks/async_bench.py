"""Sync vs buffered-async aggregation under stragglers.

The bench trains FedDeper twice on the same non-i.i.d task with the same
heavy-tailed client delays and reports *simulated wall-clock* and rounds
to a target global train loss.  The sync server pays max(delay of the
sampled cohort) per round; the async server (core/async_rounds.py) pays
only buffer-fill time, discounting stale uploads by (1+s)^-alpha.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import build_task, csv_row
from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedDeper, SimConfig,
                        init_async_state, init_sim_state, make_async_round_fn,
                        make_global_eval, make_round_fn,
                        peek_sampled_clients)
from repro.models import init_classifier


def async_vs_sync(quick=True) -> List[str]:
    cfg = MLP_MNIST
    n, m, tau, batch = 20, 8, 5, 32
    target = 0.35 if quick else 0.15
    max_rounds = 60 if quick else 500
    task = build_task(cfg, n_clients=n)
    train_eval = make_global_eval(task["apply_loss"], task["train_flat"])
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    x0 = init_classifier(cfg, jax.random.PRNGKey(42))
    acfg = AsyncSimConfig(n_clients=n, m_concurrent=m, buffer_size=m // 2,
                          tau=tau, batch_size=batch, alpha=0.5, delay=10.0,
                          delay_dist="lognormal", delay_sigma=1.2, seed=1)
    delays = acfg.client_delays()
    rows = []

    # --- synchronous: each round blocks on the slowest sampled client
    sim = SimConfig(n_clients=n, m_sampled=m, tau=tau, batch_size=batch,
                    seed=1)
    state = init_sim_state(sim, strategy, x0)
    rf = make_round_fn(sim, strategy, task["grad_fn"], task["data"])
    t0, t_sim, rounds = time.perf_counter(), 0.0, max_rounds
    for k in range(max_rounds):
        idx = np.asarray(peek_sampled_clients(state, sim))
        t_sim += float(delays[idx].max())
        state, _ = rf(state)
        if float(train_eval(state)["test_loss"]) <= target:
            rounds = k + 1
            break
    us = (time.perf_counter() - t0) / max(rounds, 1) * 1e6
    rows.append(csv_row("async_bench_sync", us,
                        {"rounds_to_target": rounds, "sim_time": t_sim,
                         "target_loss": target}))
    sync_time = t_sim

    # --- buffered async on the same delays
    state = init_async_state(acfg, strategy, x0)
    arf = make_async_round_fn(acfg, strategy, task["grad_fn"], task["data"])
    t0, t_sim, aggs, stale = time.perf_counter(), 0.0, 2 * max_rounds, 0.0
    for k in range(2 * max_rounds):
        state, metrics = arf(state)
        t_sim = float(metrics["sim_time"])
        stale = max(stale, float(metrics["staleness_max"]))
        if float(train_eval(state)["test_loss"]) <= target:
            aggs = k + 1
            break
    us = (time.perf_counter() - t0) / max(aggs, 1) * 1e6
    rows.append(csv_row("async_bench_buffered", us,
                        {"aggregations_to_target": aggs, "sim_time": t_sim,
                         "staleness_max": stale,
                         "speedup_vs_sync": sync_time / max(t_sim, 1e-9)}))
    return rows
