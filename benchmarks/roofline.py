"""Roofline benchmark: renders the per-(arch x shape x mesh) three-term
table from the dry-run JSONL (experiments/dryrun.jsonl).

This is the harness behind EXPERIMENTS.md §Roofline -- the dry-run sweep
(scripts/run_dryruns.sh) produces the records; this module aggregates,
identifies the dominant term, and prints CSV rows.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "experiments", "dryrun.jsonl")


def load_records(path: str = DEFAULT_PATH) -> List[Dict]:
    if not os.path.exists(path):
        return []
    best = {}
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"),
                   rec.get("variant", "feddeper"))
            best[key] = rec  # last record wins (reruns supersede)
    return list(best.values())


def rows(path: str = DEFAULT_PATH) -> List[str]:
    out = []
    recs = sorted(load_records(path),
                  key=lambda r: (r.get("arch", ""), r.get("shape", ""),
                                 r.get("mesh", "")))
    n_ok = n_skip = n_err = 0
    for r in recs:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("status") == "skipped":
            n_skip += 1
            out.append(f"{name},0.0,status=skipped")
            continue
        if r.get("status") != "ok":
            n_err += 1
            out.append(f"{name},0.0,status=error")
            continue
        n_ok += 1
        d = {
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
        }
        dstr = ";".join(f"{k}={v}" for k, v in d.items())
        out.append(f"{name},{r.get('compile_s', 0) * 1e6:.0f},{dstr}")
    out.append(f"roofline_summary,0.0,ok={n_ok};skipped={n_skip};"
               f"errors={n_err}")
    return out
