"""Round-engine benchmark: the tracked perf baseline for the hot path.

Times one jitted round (sync) / one buffered aggregation (async) for all
four strategies, and for FedDeper both sides of every fusion seam the
round engine has:

* ``*_unfused``        -- the reference engine: two serial grad passes per
                          local step, per-step tree-map (or per-LEAF
                          Pallas launch) updates, undonated round buffers;
* ``*_fused``          -- the fused engine: one joint twin-gradient pass
                          (``twin_grad_fn``), fused y/v update, donated
                          round state;
* ``*_pallas_unfused`` -- pre-engine Pallas path: one launch per pytree
                          leaf per step (interpret emulation off-TPU);
* ``*_pallas_fused``   -- single whole-tree launch per step with the
                          mixing/upload tail emitted by the final launch;
* ``*_mesh``           -- the fused engine under the MESH placement
                          (cohort dim on the mesh's client axis through
                          shard_map, delta-mean as one psum), interleaved
                          against the identical vmap row so the tracked
                          ``speedup_vs_vmap`` ratio prices the shard_map
                          lowering (1-device mesh on this container).

Every run rewrites ``BENCH_round_engine.json`` at the repo root so each
PR leaves a perf trajectory.  Schema (validated by ``validate_bench``):

    { bench_name: { "us_per_round": float,        # best-of-reps mean
                    "peak_bytes":   int | null,   # device peak, if known
                    "config":       { ... } } }   # exact knobs + speedups
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional

import jax

from benchmarks.common import build_task, csv_row
from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedAvg, FedDeper, FedProx, Scaffold,
                        SimConfig, init_async_state, init_sim_state,
                        make_async_round_fn, make_placement, make_round_fn,
                        twin_grad_fn)
from repro.models import init_classifier

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_round_engine.json"

# the default quick-bench operating point: the paper's cross-silo setting
# (MLP on MNIST-like data, n=10 full participation, tau=5 local steps)
QUICK = dict(n=10, m=10, tau=5, batch=32)
FULL = dict(n=100, m=20, tau=10, batch=32)


def _peak_bytes() -> Optional[int]:
    try:
        stats = jax.local_devices()[0].memory_stats()
        if stats and "peak_bytes_in_use" in stats:
            return int(stats["peak_bytes_in_use"])
    except Exception:  # noqa: BLE001  (backend without memory stats)
        pass
    return None


class _Prepared:
    """A compiled bench: round_fn plus its rolling state.  The warmup
    round both compiles and (donating engines) consumes the init state,
    so every timed block continues from post-warmup state like a real
    run."""

    def __init__(self, round_fn, state, cfg):
        self.round_fn, self.cfg = round_fn, cfg
        self.state, _ = round_fn(state)
        jax.block_until_ready(jax.tree.leaves(self.state["x"])[0])
        self.best = float("inf")
        self.peak_bytes = None

    def block(self, rounds: int) -> float:
        """Run one timed block; returns its per-round seconds (callers
        pairing two benches take window-local minima from the return
        value so a ratio never mixes timings from different blocks)."""
        t0 = time.perf_counter()
        s = self.state
        for _ in range(rounds):
            s, _ = self.round_fn(s)
        jax.block_until_ready(jax.tree.leaves(s["x"])[0])
        per_round = (time.perf_counter() - t0) / rounds
        self.best = min(self.best, per_round)
        self.state = s
        return per_round

    @property
    def us(self) -> float:
        return 1e6 * self.best


def _prep_sync(task, x0, scale, strategy, *, donate, twin,
               placement=None):
    sim = SimConfig(n_clients=scale["n"], m_sampled=scale["m"],
                    tau=scale["tau"], batch_size=scale["batch"], seed=0)
    grad_fn = twin_grad_fn(task["apply_loss"]) if twin else task["grad_fn"]
    pl = make_placement(placement) if placement else None
    rf = make_round_fn(sim, strategy, grad_fn, task["data"], donate=donate,
                       placement=pl)
    cfg = dict(regime="sync", model=MLP_MNIST.name, donate=donate,
               twin_grads=twin, placement=placement or "vmap", **scale)
    for k in ("use_pallas", "fuse_grads"):
        if hasattr(strategy, k):
            cfg[k] = getattr(strategy, k)
    return _Prepared(rf, init_sim_state(sim, strategy, x0, placement=pl),
                     cfg)


def _prep_async(task, x0, scale, strategy, *, donate, twin):
    acfg = AsyncSimConfig(n_clients=scale["n"], m_concurrent=scale["m"],
                          buffer_size=scale["m"], tau=scale["tau"],
                          batch_size=scale["batch"], alpha=0.5, delay=10.0,
                          delay_dist="lognormal", seed=0)
    grad_fn = twin_grad_fn(task["apply_loss"]) if twin else task["grad_fn"]
    arf = make_async_round_fn(acfg, strategy, grad_fn, task["data"],
                              donate=donate)
    cfg = dict(regime="async", model=MLP_MNIST.name, donate=donate,
               twin_grads=twin, alpha=acfg.alpha, delay=acfg.delay, **scale)
    for k in ("use_pallas", "fuse_grads"):
        if hasattr(strategy, k):
            cfg[k] = getattr(strategy, k)
    return _Prepared(arf, init_async_state(acfg, strategy, x0), cfg)


def validate_bench(obj) -> None:
    """Raise ValueError unless ``obj`` matches the BENCH schema."""
    if not isinstance(obj, dict) or not obj:
        raise ValueError("bench json must be a non-empty dict")
    for name, entry in obj.items():
        if not isinstance(name, str):
            raise ValueError(f"bench name {name!r} is not a string")
        if not isinstance(entry, dict):
            raise ValueError(f"{name}: entry must be a dict")
        missing = {"us_per_round", "peak_bytes", "config"} - set(entry)
        if missing:
            raise ValueError(f"{name}: missing keys {sorted(missing)}")
        us = entry["us_per_round"]
        if not isinstance(us, (int, float)) or us <= 0:
            raise ValueError(f"{name}: us_per_round must be positive")
        pb = entry["peak_bytes"]
        if pb is not None and (not isinstance(pb, int) or pb < 0):
            raise ValueError(f"{name}: peak_bytes must be null or int >= 0")
        if not isinstance(entry["config"], dict):
            raise ValueError(f"{name}: config must be a dict")


ETA = dict(eta=0.05)
DEPER = dict(eta=0.05, rho=0.03, lam=0.5)


def _benches():
    """name -> (kind, strategy, opts).  FedDeper appears once per engine
    seam; the other strategies track the plain (donated) engine."""
    return {
        "fedavg_sync": ("sync", FedAvg(**ETA), dict(donate=True,
                                                    twin=False)),
        "fedprox_sync": ("sync", FedProx(mu=1.0, **ETA), dict(donate=True,
                                                              twin=False)),
        "scaffold_sync": ("sync", Scaffold(**ETA), dict(donate=True,
                                                        twin=False)),
        "feddeper_sync_unfused": (
            "sync", FedDeper(fuse_grads=False, **DEPER),
            dict(donate=False, twin=False)),
        "feddeper_sync_fused": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True)),
        "feddeper_sync_pallas_unfused": (
            "sync", FedDeper(use_pallas=True, fuse_grads=False, **DEPER),
            dict(donate=False, twin=False, slow_pallas=True)),
        "feddeper_sync_pallas_fused": (
            "sync", FedDeper(use_pallas=True, fuse_grads=True, **DEPER),
            dict(donate=True, twin=True)),
        # the fused engine with the cohort dim on the mesh's client axis
        # (1-device mesh on this container: measures the shard_map + psum
        # lowering overhead against the identical vmap round)
        "feddeper_sync_mesh": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, placement="mesh")),
        "feddeper_async_unfused": (
            "async", FedDeper(fuse_grads=False, **DEPER),
            dict(donate=False, twin=False)),
        "feddeper_async_fused": (
            "async", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True)),
    }


# rows whose config records a speedup ratio against a reference row,
# timed in INTERLEAVED rep blocks so machine drift cancels out of the
# tracked ratio: name -> (reference row, config key for the ratio)
_SPEEDUP_PAIRS = {
    "feddeper_sync_fused": ("feddeper_sync_unfused", "speedup_vs_unfused"),
    "feddeper_sync_pallas_fused": ("feddeper_sync_pallas_unfused",
                                   "speedup_vs_unfused"),
    "feddeper_async_fused": ("feddeper_async_unfused",
                             "speedup_vs_unfused"),
    # placement ratio: mesh vs the identical vmap round (<= 1.0 expected
    # on a 1-device mesh -- it prices the shard_map lowering)
    "feddeper_sync_mesh": ("feddeper_sync_fused", "speedup_vs_vmap"),
}


def round_engine_rows(quick: bool = True, *,
                      include: Optional[Iterable[str]] = None,
                      rounds: Optional[int] = None, reps: int = 4,
                      out_path: Optional[Path] = BENCH_PATH) -> List[str]:
    """Run the engine benches, rewrite BENCH_round_engine.json (unless
    ``out_path=None``), return CSV rows.  ``include`` limits to a subset
    (CI smoke); ``rounds`` overrides the per-bench round count."""
    scale = QUICK if quick else FULL
    task = build_task(MLP_MNIST, scale["n"])
    x0 = init_classifier(MLP_MNIST, jax.random.PRNGKey(42))
    prepared: Dict[str, _Prepared] = {}
    n_rounds: Dict[str, int] = {}
    for name, (kind, strategy, opts) in _benches().items():
        if include is not None and name not in include:
            continue
        # the per-leaf interpret path is ~10x a treemap round on CPU:
        # keep its timed block short so the bench stays runnable
        n_rounds[name] = rounds if rounds is not None else \
            (3 if opts.get("slow_pallas") else (12 if quick else 30))
        if kind == "sync":
            prepared[name] = _prep_sync(task, x0, scale, strategy,
                                        donate=opts["donate"],
                                        twin=opts["twin"],
                                        placement=opts.get("placement"))
        else:
            prepared[name] = _prep_async(task, x0, scale, strategy,
                                         donate=opts["donate"],
                                         twin=opts["twin"])
    # fused/unfused pairs run INTERLEAVED rep blocks so machine-speed
    # drift between the two sides cancels out of the tracked ratio;
    # everything else runs its reps back to back
    # peak_bytes is read right after a bench's own timed blocks; device
    # peaks are cumulative (no portable reset), so the value means "peak
    # observed by the time this bench finished" -- null off-TPU/GPU
    paired = set()
    pair_ratio: Dict[str, float] = {}
    for name, (ref, _key) in _SPEEDUP_PAIRS.items():
        if name in prepared and ref in prepared:
            paired.update((name, ref))
            # the ratio comes from THIS pair's interleaved window only: a
            # bench appearing in two pairs (feddeper_sync_fused) would
            # otherwise contribute a global best taken under different
            # machine load than its comparator's
            best_ref = best_name = float("inf")
            for _ in range(reps):
                best_ref = min(best_ref, prepared[ref].block(n_rounds[ref]))
                best_name = min(best_name,
                                prepared[name].block(n_rounds[name]))
            pair_ratio[name] = best_ref / best_name
            prepared[ref].peak_bytes = prepared[name].peak_bytes = \
                _peak_bytes()
    for name, p in prepared.items():
        if name not in paired:
            for _ in range(reps):
                p.block(n_rounds[name])
            p.peak_bytes = _peak_bytes()

    results: Dict[str, Dict] = {}
    for name, p in prepared.items():
        p.cfg["rounds"] = n_rounds[name]
        results[name] = {"us_per_round": p.us, "peak_bytes": p.peak_bytes,
                         "config": p.cfg}

    rows = []
    for name, entry in results.items():
        derived = {"rounds": entry["config"]["rounds"]}
        pair = _SPEEDUP_PAIRS.get(name)
        if pair and name in pair_ratio:
            speedup = pair_ratio[name]
            entry["config"][pair[1]] = round(speedup, 3)
            derived[pair[1]] = speedup
        rows.append(csv_row(f"round_engine/{name}", entry["us_per_round"],
                            derived))

    if out_path is not None and results:
        written = results
        if include is not None and out_path.exists():
            # subset runs (CI smoke) refresh their rows in place, keeping
            # the rest of the tracked baseline intact
            try:
                written = json.loads(out_path.read_text())
            except json.JSONDecodeError:
                written = {}
            written.update(results)
        validate_bench(written)
        out_path.write_text(json.dumps(written, indent=2, sort_keys=True)
                            + "\n")
    return rows
