"""Round-engine benchmark: the tracked perf baseline for the hot path.

Times one jitted round (sync) / one buffered aggregation (async) for all
four strategies, and for FedDeper both sides of every fusion seam the
round engine has:

* ``*_unfused``        -- the reference engine: two serial grad passes per
                          local step, per-step tree-map (or per-LEAF
                          Pallas launch) updates, undonated round buffers;
* ``*_fused``          -- the fused engine: one joint twin-gradient pass
                          (``twin_grad_fn``), fused y/v update, donated
                          round state;
* ``*_pallas_unfused`` -- pre-engine Pallas path: one launch per pytree
                          leaf per step (interpret emulation off-TPU);
* ``*_pallas_fused``   -- single whole-tree launch per step with the
                          mixing/upload tail emitted by the final launch;
* ``*_mesh``           -- the fused engine under the MESH placement
                          (cohort dim on the mesh's client axis through
                          shard_map, delta-mean as one psum), interleaved
                          against the identical vmap row so the tracked
                          ``speedup_vs_vmap`` ratio prices the shard_map
                          lowering (1-device mesh on this container); the
                          ``async_mesh`` row does the same for the async
                          regime (padded dispatch cohorts + the
                          staleness-weighted mean lowered to one psum);
* ``*_block{K}``       -- the scan-compiled block driver
                          (``engine.make_block_fn``): K rounds per jitted
                          ``lax.scan`` call, one host sync + donation
                          handoff per block, interleaved against the
                          host-loop row it is bitwise-equal to
                          (``speedup_vs_loop``); the vmap K in {4, 12}
                          rows also record live-memory scaling with K,
                          and ``mesh_block4`` prices the scan under the
                          mesh placement;
* ``*_identity/q8/topk`` -- the comm layer (repro/comm): identity pins
                          the compression path's overhead against the
                          dense fused row (``speedup_vs_dense``); q8 and
                          topk:0.1 price real compressors and track
                          ``uplink_bytes_per_round`` -- the bandwidth
                          axis of the baseline;
* ``*_virtual_n{N}``    -- the virtual client store (core/store.py) at
                          population scales a dense store cannot reach:
                          only the sampled cohort's rows live on device
                          (reconstructible backing tier, on-demand
                          synthetic client data), so ``peak_bytes``
                          stays O(m) while n grows 100-10000x; the rows
                          additionally track ``store_bytes`` -- the
                          host-side backing-tier footprint, O(touched
                          rows) for the recon tier.

Every run rewrites ``BENCH_round_engine.json`` at the repo root so each
PR leaves a perf trajectory.  Schema (validated by ``validate_bench``;
unknown keys rejected):

    { bench_name: { "us_per_round": float,        # best-of-reps mean
                    "peak_bytes":   int,          # temp+output bytes of
                                                  # the compiled round /
                                                  # block executable
                    "uplink_bytes_per_round": int,  # compression rows
                                                    # only (required
                                                    # there): wire bytes
                                                    # one round uploads
                    "config":       { ... } } }   # exact knobs + speedups

``check_speedups`` is the CI regression gate: a smoke run's
``speedup_vs_*`` ratios must stay above ``SPEEDUP_TOL`` x the tracked
baseline's, else the bench lane fails (scripts/ci.sh).

``peak_bytes`` comes from ``compiled.memory_analysis()`` (XLA's static
allocation plan: temp buffers + outputs), NOT from runtime device stats
-- it is deterministic, available on every backend including CPU, and
null is a schema error.  Async rows probe their dominant jitted pieces
(full-size dispatch + aggregation) the same way and record the max.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional

import jax
import numpy as np

from benchmarks.common import SyntheticClientData, build_task, csv_row
from repro.comm import make_compressor, uplink_bytes_per_round
from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedAvg, FedDeper, FedProx, Scaffold,
                        SimConfig, init_async_state, init_sim_state,
                        make_async_round_fn, make_block_fn, make_global_eval,
                        make_layout, make_placement, make_robust,
                        make_round_fn, state_store_bytes, twin_grad_fn)
from repro.faults import make_faults
from repro.core.engine import make_per_client
from repro.core.strategies import tmap
from repro.models import init_classifier

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_round_engine.json"

# the default quick-bench operating point: the paper's cross-silo setting
# (MLP on MNIST-like data, n=10 full participation, tau=5 local steps)
QUICK = dict(n=10, m=10, tau=5, batch=32)
FULL = dict(n=100, m=20, tau=10, batch=32)


def _compiled_peak(jitted, *args):
    """AOT-lower ``jitted`` for ``args`` (arrays or ShapeDtypeStructs);
    returns ``(compiled, peak)`` where peak = temp + output bytes of the
    executable's static allocation plan -- the live-memory price of one
    call, deterministic and backend-independent
    (``compiled.memory_analysis()``) -- or ``(None, None)`` when the AOT
    path is unavailable.  THE one definition of peak_bytes: sync rows
    and the async probe both report it."""
    try:
        compiled = jitted.lower(*args).compile()
        ma = compiled.memory_analysis()
        return compiled, (int(ma.temp_size_in_bytes) +
                          int(ma.output_size_in_bytes))
    except Exception:  # noqa: BLE001  (AOT path unavailable)
        return None, None


def _sds(tree, lead=()):
    """ShapeDtypeStruct pytree (optionally with extra leading dims) --
    the AOT lowering probe's stand-in arguments."""
    return tmap(lambda t: jax.ShapeDtypeStruct(tuple(lead) + t.shape,
                                               t.dtype), tree)


class _Prepared:
    """A compiled bench: round_fn plus its rolling state.  The warmup
    round both compiles and (donating engines) consumes the init state,
    so every timed block continues from post-warmup state like a real
    run.  ``rounds_per_call`` is the number of simulated rounds one
    ``round_fn`` call advances (1 for the host loop, K for scan blocks);
    timings are always normalized per ROUND.

    Jitted round_fns are AOT-lowered ONCE: the same ``Compiled`` object
    supplies ``memory_analysis()`` (peak_bytes) and then serves the
    warmup + timed calls -- ``lower().compile()`` does not seed the jit
    dispatch cache on this jax, so calling the wrapped fn afterwards
    would compile the identical computation a second time (AOT calls are
    bitwise-equal to the jit path and honor donation; verified on CPU
    jax 0.4.37)."""

    def __init__(self, round_fn, state, cfg, *, rounds_per_call: int = 1,
                 peak_bytes: Optional[int] = None,
                 uplink_bytes: Optional[int] = None):
        self.cfg = cfg
        self.rounds_per_call = rounds_per_call
        self.uplink_bytes = uplink_bytes
        if peak_bytes is None and hasattr(round_fn, "lower"):
            compiled, peak_bytes = _compiled_peak(round_fn, state)
            if compiled is not None:
                round_fn = compiled
        self.round_fn = round_fn
        self.peak_bytes = peak_bytes
        # fault benches report screened lanes per round: the metric
        # arrays are APPENDED while timing (device handles only -- no
        # host sync inside the window) and reduced at report time
        self._screened: list = []
        self.state, mets = round_fn(state)
        # rounds this bench has ADVANCED from x0 (warmup + every timed
        # block): robust rows replay an un-defended reference for exactly
        # this many rounds so the attack x defense matrix is like-for-like
        self.rounds_done = self.rounds_per_call
        # robust rows fill this post-timing (clean/attacked/defended
        # accuracy triple); validate_bench rejects a robust row without it
        self.robust_matrix = None
        self._note(mets)
        jax.block_until_ready(jax.tree.leaves(self.state["x"])[0])
        if self.peak_bytes is None:
            # virtual-store round_fns are host wrappers (no .lower); they
            # AOT-compile their jitted block on first call and publish
            # the same temp+output measure as an attribute
            self.peak_bytes = getattr(self.round_fn, "peak_bytes", None)
        self.best = float("inf")

    def _note(self, mets):
        if isinstance(mets, dict) and "screened" in mets:
            self._screened.append(mets["screened"])

    @property
    def screened_per_round(self) -> Optional[float]:
        """Mean screened-lane count over every round this bench ran
        (warmup + timed), or None when the round_fn tracks no screening
        (no faults in play)."""
        if not self._screened:
            return None
        vals = [np.asarray(a) for a in self._screened]
        return float(sum(v.sum() for v in vals) /
                     sum(v.size for v in vals))

    def block(self, rounds: int) -> float:
        """Run one timed block of ``rounds`` simulated rounds (callers
        keep it a multiple of ``rounds_per_call``); returns its per-round
        seconds (callers pairing two benches take window-local minima
        from the return value so a ratio never mixes timings from
        different blocks)."""
        calls = max(1, rounds // self.rounds_per_call)
        t0 = time.perf_counter()
        s = self.state
        for _ in range(calls):
            s, mets = self.round_fn(s)
            self._note(mets)
        jax.block_until_ready(jax.tree.leaves(s["x"])[0])
        per_round = (time.perf_counter() - t0) / (calls *
                                                  self.rounds_per_call)
        self.best = min(self.best, per_round)
        self.rounds_done += calls * self.rounds_per_call
        self.state = s
        return per_round

    @property
    def us(self) -> float:
        return 1e6 * self.best


def _prep_sync(task, x0, scale, strategy, *, donate, twin,
               placement=None, block=None, compress=None, faults=None,
               store=None, robust=None):
    sim = SimConfig(n_clients=scale["n"], m_sampled=scale["m"],
                    tau=scale["tau"], batch_size=scale["batch"], seed=0)
    grad_fn = twin_grad_fn(task["apply_loss"]) if twin else task["grad_fn"]
    pl = make_placement(placement) if placement else None
    comp = make_compressor(compress) if compress else None
    fl = make_faults(faults) if faults else None
    rb = make_robust(robust) if robust else None
    layout = make_layout(store)
    if block:
        rf = make_block_fn(sim, strategy, grad_fn, task["data"],
                           block_size=block, donate=donate, placement=pl,
                           compressor=comp, faults=fl, layout=layout,
                           robust=rb)
    else:
        rf = make_round_fn(sim, strategy, grad_fn, task["data"],
                           donate=donate, placement=pl, compressor=comp,
                           faults=fl, layout=layout, robust=rb)
    cfg = dict(regime="sync", model=MLP_MNIST.name, donate=donate,
               twin_grads=twin, placement=placement or "vmap", **scale)
    if block:
        cfg["block_rounds"] = block
    if layout.virtual:
        # virtual rows additionally track store_bytes at the entry level
        # (validate_bench requires it when config carries a virtual
        # "store" spec)
        cfg["store"] = layout.spec
    if faults:
        # fault rows additionally track screened_per_round at the entry
        # level (validate_bench requires it when config carries "faults")
        cfg["faults"] = faults
    if rb is not None:
        # robust rows additionally track the attack x defense accuracy
        # matrix at the entry level (validate_bench requires it when
        # config carries "robust")
        cfg["robust"] = rb.spec
    uplink = None
    if compress:
        # compression rows track their wire cost next to us_per_round /
        # peak_bytes (validate_bench requires it on such rows)
        cfg["compress"] = compress
        uplink = uplink_bytes_per_round(comp, strategy, x0, scale["m"])
    for k in ("use_pallas", "fuse_grads"):
        if hasattr(strategy, k):
            cfg[k] = getattr(strategy, k)
    return _Prepared(rf, init_sim_state(sim, strategy, x0, placement=pl,
                                        compressor=comp, layout=layout),
                     cfg, rounds_per_call=block or 1, uplink_bytes=uplink)


def _async_peak_bytes(arf, acfg, task, strategy, grad_fn, state
                      ) -> Optional[int]:
    """Max temp+output bytes over the async regime's jitted pieces, AOT-
    lowered at their LARGEST shapes: a full ``m_concurrent`` dispatch
    (tau-scan cohort training -- the dominant allocation) and a full-
    buffer weighted aggregation.  The host-side event loop itself
    allocates nothing device-side beyond these."""
    f, tau, b = acfg.m_concurrent, acfg.tau, acfg.batch_size
    x, server, clients = state["x"], state["server"], state["clients"]
    ctx = jax.eval_shape(strategy.broadcast, x, server)
    cs = _sds(tmap(lambda t: t[0], clients), (f,)) \
        if jax.tree.leaves(clients) else {}
    batches = tmap(lambda t: jax.ShapeDtypeStruct(
        (f, tau, b) + t.shape[2:], t.dtype), task["data"])
    parts = getattr(arf, "jitted_parts", {})
    peaks = []
    tc = parts.get("train_cohort")
    if tc is not None:
        _, p = _compiled_peak(tc, _sds(x, (f,)), _sds(ctx, (f,)), cs,
                              batches)
        if p is not None:
            peaks.append(p)
        # upload shapes for the aggregation probe come from the abstract
        # per-client round (no FLOPs run under eval_shape)
        per_client = make_per_client(strategy, grad_fn)
        _, upload, _, _ = jax.eval_shape(
            per_client, _sds(x), _sds(ctx),
            _sds(tmap(lambda t: t[0], clients))
            if jax.tree.leaves(clients) else {},
            tmap(lambda t: jax.ShapeDtypeStruct((tau, b) + t.shape[2:],
                                                t.dtype), task["data"]))
        agg = parts.get("agg_weighted" if acfg.alpha else "agg_plain")
        if agg is not None:
            w = (jax.ShapeDtypeStruct((acfg.buffer_size,),
                                      "float32"),) if acfg.alpha else ()
            _, p = _compiled_peak(agg, _sds(x), _sds(server),
                                  _sds(upload, (acfg.buffer_size,)), *w)
            if p is not None:
                peaks.append(p)
    return max(peaks) if peaks else None


def _prep_async(task, x0, scale, strategy, *, donate, twin,
                placement=None):
    acfg = AsyncSimConfig(n_clients=scale["n"], m_concurrent=scale["m"],
                          buffer_size=scale["m"], tau=scale["tau"],
                          batch_size=scale["batch"], alpha=0.5, delay=10.0,
                          delay_dist="lognormal", seed=0)
    grad_fn = twin_grad_fn(task["apply_loss"]) if twin else task["grad_fn"]
    pl = make_placement(placement) if placement else None
    arf = make_async_round_fn(acfg, strategy, grad_fn, task["data"],
                              donate=donate, placement=pl)
    cfg = dict(regime="async", model=MLP_MNIST.name, donate=donate,
               twin_grads=twin, alpha=acfg.alpha, delay=acfg.delay,
               placement=placement or "vmap", **scale)
    for k in ("use_pallas", "fuse_grads"):
        if hasattr(strategy, k):
            cfg[k] = getattr(strategy, k)
    state = init_async_state(acfg, strategy, x0, placement=pl)
    peak = _async_peak_bytes(arf, acfg, task, strategy, grad_fn, state)
    return _Prepared(arf, state, cfg, peak_bytes=peak)


# every key a bench entry may carry; anything else is a schema error so
# future bench edits fail loudly in the smoke lane instead of silently
# shipping unvalidated fields
_ENTRY_KEYS = {"us_per_round", "peak_bytes", "config",
               "uplink_bytes_per_round", "screened_per_round",
               "store_bytes", "robust_matrix"}

# the attack x defense accuracy matrix every robust row must publish:
# the same model attacked and undefended (plain mean), attacked and
# defended (the row's reducer), and the paired clean reference
_ROBUST_MATRIX_KEYS = {"clean", "attacked_mean", "defended"}


def validate_bench(obj) -> None:
    """Raise ValueError unless ``obj`` matches the BENCH schema.
    Unknown entry keys are rejected; rows whose config records a
    ``compress`` spec must also track ``uplink_bytes_per_round``, and
    rows whose config records a ``faults`` spec must track
    ``screened_per_round`` (forbidden elsewhere -- a screened count on a
    fault-free row means the harness mixed up its round_fns)."""
    if not isinstance(obj, dict) or not obj:
        raise ValueError("bench json must be a non-empty dict")
    for name, entry in obj.items():
        if not isinstance(name, str):
            raise ValueError(f"bench name {name!r} is not a string")
        if not isinstance(entry, dict):
            raise ValueError(f"{name}: entry must be a dict")
        missing = {"us_per_round", "peak_bytes", "config"} - set(entry)
        if missing:
            raise ValueError(f"{name}: missing keys {sorted(missing)}")
        unknown = set(entry) - _ENTRY_KEYS
        if unknown:
            raise ValueError(f"{name}: unknown keys {sorted(unknown)} "
                             f"(schema allows {sorted(_ENTRY_KEYS)})")
        us = entry["us_per_round"]
        if not isinstance(us, (int, float)) or us <= 0:
            raise ValueError(f"{name}: us_per_round must be positive")
        pb = entry["peak_bytes"]
        # null was accepted while peak came from (CPU-absent) device
        # stats; compiled.memory_analysis() exists on every backend, so
        # a missing peak is now a harness bug, not a platform gap
        if not isinstance(pb, int) or isinstance(pb, bool) or pb <= 0:
            raise ValueError(f"{name}: peak_bytes must be a positive int "
                             f"(got {pb!r})")
        if not isinstance(entry["config"], dict):
            raise ValueError(f"{name}: config must be a dict")
        if "compress" in entry["config"]:
            ub = entry.get("uplink_bytes_per_round")
            if not isinstance(ub, int) or isinstance(ub, bool) or ub <= 0:
                raise ValueError(
                    f"{name}: compression rows must track "
                    f"uplink_bytes_per_round as a positive int (got "
                    f"{ub!r})")
        if "faults" in entry["config"]:
            sp = entry.get("screened_per_round")
            if not isinstance(sp, (int, float)) or isinstance(sp, bool) \
                    or sp < 0:
                raise ValueError(
                    f"{name}: fault rows must track screened_per_round "
                    f"as a non-negative number (got {sp!r})")
        elif "screened_per_round" in entry:
            raise ValueError(
                f"{name}: screened_per_round on a row whose config has "
                "no 'faults' spec")
        if "robust" in entry["config"]:
            rm = entry.get("robust_matrix")
            if not isinstance(rm, dict) or \
                    set(rm) != _ROBUST_MATRIX_KEYS or \
                    not all(isinstance(v, (int, float)) and
                            not isinstance(v, bool) for v in rm.values()):
                raise ValueError(
                    f"{name}: robust rows must track robust_matrix as a "
                    f"dict with float keys {sorted(_ROBUST_MATRIX_KEYS)} "
                    f"(got {rm!r})")
        elif "robust_matrix" in entry:
            raise ValueError(
                f"{name}: robust_matrix on a row whose config has no "
                "'robust' spec (nothing defends a plain-mean row)")
        if str(entry["config"].get("store", "")).startswith("virtual"):
            sb = entry.get("store_bytes")
            if not isinstance(sb, int) or isinstance(sb, bool) or sb <= 0:
                raise ValueError(
                    f"{name}: virtual-store rows must track store_bytes "
                    f"(host backing-tier footprint) as a positive int "
                    f"(got {sb!r})")
        elif "store_bytes" in entry:
            raise ValueError(
                f"{name}: store_bytes on a row whose config has no "
                "virtual 'store' spec (dense stores live in peak_bytes)")


# regression gate: a smoke ratio may drop to this fraction of its
# tracked value before CI fails -- generous because the 2-round reps=1
# smoke is noisy, but tight enough that a lost fusion seam (ratio -> ~1)
# or a broken block driver (ratio -> <1) trips it
SPEEDUP_TOL = 0.5

# memory gate: a smoke row's peak_bytes may grow to this multiple of its
# tracked value before CI fails.  peak_bytes is the compiled
# executable's STATIC allocation plan -- deterministic, so unlike the
# timing ratios the tolerance covers layout jitter across jax/XLA
# versions, not run-to-run noise; a dense store sneaking back into a
# virtual row (a 10-100x jump at n=1k) clears it by an order of
# magnitude
MEM_TOL = 1.5


def check_speedups(smoke: Dict, tracked: Dict,
                   tol: float = SPEEDUP_TOL,
                   mem_tol: float = MEM_TOL) -> List[str]:
    """Compare every ``speedup_vs_*`` ratio a smoke run produced against
    the tracked baseline row of the same name: returns failure messages
    for each ratio below ``tol * tracked`` (empty = gate passes).  Rows
    or ratios missing from either side are skipped -- the gate watches
    regressions of what IS tracked, not coverage.

    Also gates MEMORY: when both sides of a row carry an integer
    ``peak_bytes``, the smoke value must stay at or under ``mem_tol`` x
    the tracked one -- the live-memory analogue of the timing gate, and
    the CI tripwire for the virtual store's O(cohort) claim."""
    fails = []
    for name, entry in smoke.items():
        ref = tracked.get(name)
        if not isinstance(ref, dict):
            continue
        pb, base_pb = entry.get("peak_bytes"), ref.get("peak_bytes")
        if isinstance(pb, int) and not isinstance(pb, bool) \
                and isinstance(base_pb, int) and not isinstance(base_pb,
                                                                bool) \
                and base_pb > 0 and pb > base_pb * mem_tol:
            fails.append(
                f"{name}.peak_bytes: smoke {pb} > ceiling "
                f"{int(base_pb * mem_tol)} (tracked {base_pb} x "
                f"mem_tol {mem_tol})")
        for key, val in entry.get("config", {}).items():
            if not key.startswith("speedup_vs_"):
                continue
            base = ref.get("config", {}).get(key)
            if not isinstance(base, (int, float)) or \
                    not isinstance(val, (int, float)):
                continue
            floor = base * tol
            if val < floor:
                fails.append(
                    f"{name}.{key}: smoke {val:.3f} < floor {floor:.3f} "
                    f"(tracked {base:.3f} x tol {tol})")
    return fails


ETA = dict(eta=0.05)
DEPER = dict(eta=0.05, rho=0.03, lam=0.5)


def _benches():
    """name -> (kind, strategy, opts).  FedDeper appears once per engine
    seam; the other strategies track the plain (donated) engine."""
    return {
        "fedavg_sync": ("sync", FedAvg(**ETA), dict(donate=True,
                                                    twin=False)),
        "fedprox_sync": ("sync", FedProx(mu=1.0, **ETA), dict(donate=True,
                                                              twin=False)),
        "scaffold_sync": ("sync", Scaffold(**ETA), dict(donate=True,
                                                        twin=False)),
        "feddeper_sync_unfused": (
            "sync", FedDeper(fuse_grads=False, **DEPER),
            dict(donate=False, twin=False)),
        "feddeper_sync_fused": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True)),
        # per-leaf interpret launches are ~10x a treemap round on CPU,
        # but the row still runs the SAME rounds=12 protocol as its
        # paired fused row -- like-for-like pairs beat a short bench
        "feddeper_sync_pallas_unfused": (
            "sync", FedDeper(use_pallas=True, fuse_grads=False, **DEPER),
            dict(donate=False, twin=False)),
        "feddeper_sync_pallas_fused": (
            "sync", FedDeper(use_pallas=True, fuse_grads=True, **DEPER),
            dict(donate=True, twin=True)),
        # the fused engine with the cohort dim on the mesh's client axis
        # (1-device mesh on this container: measures the shard_map + psum
        # lowering overhead against the identical vmap round)
        "feddeper_sync_mesh": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, placement="mesh")),
        # scan-compiled blocks (engine.make_block_fn): K rounds per jitted
        # call, bitwise-equal to the host-loop row they pair against; the
        # two vmap K's record how live memory scales with block size
        "feddeper_sync_block4": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, block=4)),
        "feddeper_sync_block12": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, block=12)),
        "feddeper_sync_mesh_block4": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, placement="mesh", block=4)),
        # uplink compression (repro.comm): the identity row pins the comm
        # path's overhead against the plain fused engine; q8/topk price
        # real compressors and track uplink_bytes_per_round -- the
        # bandwidth axis next to time (us_per_round) and memory
        # (peak_bytes)
        "feddeper_sync_identity": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, compress="identity")),
        "feddeper_sync_q8": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, compress="q8")),
        "feddeper_sync_topk": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, compress="topk:0.1")),
        # fault injection + screening (repro.faults): the paper's
        # unreliable-device setting at drop=0.2/corrupt=0.05 -- the row
        # tracks screened_per_round and post-bench eval accuracy next to
        # its clean reference, and the ratio prices the screening math
        # riding the round's single psum
        "feddeper_sync_faults": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True,
                 faults="drop:0.2,corrupt:0.05")),
        # Byzantine-robust aggregation (repro.robust): 20% colluding
        # lanes riding the clip boundary (negated, rescaled to exactly
        # clip_norm -- screening cannot reject them) against Krum-lite
        # filtering -- the ratio prices the gather + Gram-matrix reduce
        # against the clean fused round, and the post-timing
        # robust_matrix records the attack x defense accuracy triple
        # (clean / attacked_mean / defended) at identical round counts
        "feddeper_sync_robust": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, faults="collude:0.2,clip:2.0",
                 robust="krum:0.3")),
        # the virtual client store (core/store.py) at cross-DEVICE
        # population scales: n=1k / n=100k clients, m=10 sampled -- the
        # dense (n, params) store would need 100-10000x the cohort's
        # device memory, the virtual rows keep peak_bytes pinned at the
        # n=10 dense row's scale.  The recon backing tier + on-demand
        # SyntheticClientData mean NOTHING population-sized exists on
        # host either; store_bytes tracks the O(touched-rows) footprint
        "feddeper_sync_virtual_n1k": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, store="virtual:recon",
                 scale=dict(n=1000, m=10, tau=5, batch=32))),
        "feddeper_sync_virtual_n100k": (
            "sync", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, store="virtual:recon",
                 scale=dict(n=100000, m=10, tau=5, batch=32))),
        "feddeper_async_unfused": (
            "async", FedDeper(fuse_grads=False, **DEPER),
            dict(donate=False, twin=False)),
        "feddeper_async_fused": (
            "async", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True)),
        # the async regime under the MESH placement: padded dispatch
        # cohorts on the client axis, staleness-weighted aggregation
        # lowered to one psum (aggregate_buffer); interleaved against the
        # identical vmap async row so the ratio prices the shard_map +
        # weighted-psum lowering (1-device mesh on this container)
        "feddeper_async_mesh": (
            "async", FedDeper(fuse_grads=True, **DEPER),
            dict(donate=True, twin=True, placement="mesh")),
    }


# rows whose config records a speedup ratio against a reference row,
# timed in INTERLEAVED rep blocks so machine drift cancels out of the
# tracked ratio: name -> (reference row, config key for the ratio)
_SPEEDUP_PAIRS = {
    "feddeper_sync_fused": ("feddeper_sync_unfused", "speedup_vs_unfused"),
    "feddeper_sync_pallas_fused": ("feddeper_sync_pallas_unfused",
                                   "speedup_vs_unfused"),
    "feddeper_async_fused": ("feddeper_async_unfused",
                             "speedup_vs_unfused"),
    # async placement ratio: mesh async vs the identical vmap async row
    # (<= 1.0 expected on a 1-device mesh; prices the padded cohort_map
    # + weighted-psum aggregation lowering)
    "feddeper_async_mesh": ("feddeper_async_fused", "speedup_vs_vmap"),
    # placement ratio: mesh vs the identical vmap round (<= 1.0 expected
    # on a 1-device mesh -- it prices the shard_map lowering)
    "feddeper_sync_mesh": ("feddeper_sync_fused", "speedup_vs_vmap"),
    # scan ratio: K rounds per jitted call vs one jitted call per round
    # (the block row is bitwise-equal to its reference, so the ratio is
    # pure dispatch/sync/donation-handoff amortization)
    "feddeper_sync_block4": ("feddeper_sync_fused", "speedup_vs_loop"),
    "feddeper_sync_block12": ("feddeper_sync_fused", "speedup_vs_loop"),
    "feddeper_sync_mesh_block4": ("feddeper_sync_mesh", "speedup_vs_loop"),
    # comm ratios: compute cost of compressing the uplink, against the
    # dense round it is otherwise identical to (<= 1.0 expected -- the
    # win is the tracked uplink_bytes_per_round column, not wall time;
    # on real networks the byte column IS the wall-time column)
    "feddeper_sync_identity": ("feddeper_sync_fused", "speedup_vs_dense"),
    "feddeper_sync_q8": ("feddeper_sync_identity", "speedup_vs_dense"),
    "feddeper_sync_topk": ("feddeper_sync_identity", "speedup_vs_dense"),
    # fault ratio: screening + fault draws vs the clean fused round
    # (<= 1.0 expected -- screening's weighted mean rides the same psum,
    # so the gap is the fault-draw/clip math, not an extra collective)
    "feddeper_sync_faults": ("feddeper_sync_fused", "speedup_vs_clean"),
    # robust ratio: gather + Krum vs the clean fused round (<= 1.0
    # expected -- krum adds one all_gather and an (m, m) Gram matrix;
    # the win is the robust_matrix accuracy column, not wall time)
    "feddeper_sync_robust": ("feddeper_sync_fused", "speedup_vs_clean"),
}


def round_engine_rows(quick: bool = True, *,
                      include: Optional[Iterable[str]] = None,
                      rounds: Optional[int] = None, reps: int = 4,
                      out_path: Optional[Path] = BENCH_PATH) -> List[str]:
    """Run the engine benches, rewrite BENCH_round_engine.json (unless
    ``out_path=None``), return CSV rows.  ``include`` limits to a subset
    (CI smoke); ``rounds`` overrides the per-bench round count."""
    scale = QUICK if quick else FULL
    task = build_task(MLP_MNIST, scale["n"])
    x0 = init_classifier(MLP_MNIST, jax.random.PRNGKey(42))
    prepared: Dict[str, _Prepared] = {}
    n_rounds: Dict[str, int] = {}
    for name, (kind, strategy, opts) in _benches().items():
        if include is not None and name not in include:
            continue
        base = rounds if rounds is not None else (12 if quick else 30)
        # a scan-block bench advances `block` rounds per call: round its
        # timed window to a whole number of calls (at least one)
        k = opts.get("block", 1)
        n_rounds[name] = max(k, (base // k) * k)
        row_scale, row_task = scale, task
        if "scale" in opts:
            # population-scale rows bring their own n (too large for the
            # dense build_task arrays): same model/grad_fn, on-demand
            # synthetic per-client data in place of the (n, Ni, ...) leaves
            row_scale = opts["scale"]
            row_task = dict(task, data=SyntheticClientData(
                input_shape=MLP_MNIST.input_shape,
                n_clients=row_scale["n"], per_client=256, seed=0))
        if kind == "sync":
            prepared[name] = _prep_sync(row_task, x0, row_scale, strategy,
                                        donate=opts["donate"],
                                        twin=opts["twin"],
                                        placement=opts.get("placement"),
                                        block=opts.get("block"),
                                        compress=opts.get("compress"),
                                        faults=opts.get("faults"),
                                        store=opts.get("store"),
                                        robust=opts.get("robust"))
        else:
            prepared[name] = _prep_async(task, x0, scale, strategy,
                                         donate=opts["donate"],
                                         twin=opts["twin"],
                                         placement=opts.get("placement"))
    # fused/unfused pairs run INTERLEAVED rep blocks so machine-speed
    # drift between the two sides cancels out of the tracked ratio;
    # everything else runs its reps back to back.  peak_bytes needs no
    # timing window: it is the compiled executable's static allocation
    # plan, recorded at prep time
    paired = set()
    pair_ratio: Dict[str, float] = {}
    for name, (ref, _key) in _SPEEDUP_PAIRS.items():
        if name in prepared and ref in prepared:
            paired.update((name, ref))
            # the ratio comes from THIS pair's interleaved window only: a
            # bench appearing in two pairs (feddeper_sync_fused) would
            # otherwise contribute a global best taken under different
            # machine load than its comparator's
            best_ref = best_name = float("inf")
            for _ in range(reps):
                best_ref = min(best_ref, prepared[ref].block(n_rounds[ref]))
                best_name = min(best_name,
                                prepared[name].block(n_rounds[name]))
            pair_ratio[name] = best_ref / best_name
    for name, p in prepared.items():
        if name not in paired:
            for _ in range(reps):
                p.block(n_rounds[name])

    # fault rows additionally record post-bench eval accuracy next to the
    # clean reference's (the acceptance axis: screening keeps training
    # convergent, not just finite) -- evaluated AFTER all timed windows so
    # the eval never perturbs a timing
    fault_rows = [n for n in prepared if "faults" in prepared[n].cfg]
    if fault_rows:
        test_eval = make_global_eval(task["apply_loss"], task["test"])
        for name in fault_rows:
            p = prepared[name]
            p.cfg["eval_acc"] = round(
                float(test_eval(p.state)["test_acc"]), 4)
            ref = _SPEEDUP_PAIRS.get(name, (None,))[0]
            if ref in prepared:
                p.cfg["eval_acc_clean"] = round(
                    float(test_eval(prepared[ref].state)["test_acc"]), 4)
            if "robust" not in p.cfg:
                continue
            # the attack x defense matrix's missing cell: the SAME
            # attack with the defense off (plain weighted mean).  Runs
            # un-timed after every window, advanced to exactly the
            # rounds the defended row consumed so all three accuracies
            # price identical training budgets
            _, strat, opts = _benches()[name]
            atk = _prep_sync(task, x0, scale, strat,
                             donate=opts["donate"], twin=opts["twin"],
                             placement=opts.get("placement"),
                             block=opts.get("block"),
                             faults=opts.get("faults"))
            if p.rounds_done > atk.rounds_done:
                atk.block(p.rounds_done - atk.rounds_done)
            p.robust_matrix = {
                "defended": p.cfg["eval_acc"],
                "attacked_mean": round(
                    float(test_eval(atk.state)["test_acc"]), 4),
                "clean": p.cfg.get("eval_acc_clean", 0.0),
            }

    results: Dict[str, Dict] = {}
    for name, p in prepared.items():
        p.cfg["rounds"] = n_rounds[name]
        results[name] = {"us_per_round": p.us, "peak_bytes": p.peak_bytes,
                         "config": p.cfg}
        if p.uplink_bytes is not None:
            results[name]["uplink_bytes_per_round"] = p.uplink_bytes
        if "faults" in p.cfg:
            results[name]["screened_per_round"] = \
                round(p.screened_per_round or 0.0, 4)
        if "robust" in p.cfg:
            results[name]["robust_matrix"] = p.robust_matrix
        if "store" in p.cfg:
            # post-run backing-tier footprint: for the recon tier this is
            # O(touched rows), the bench's O(cohort)-not-O(n) receipt
            results[name]["store_bytes"] = state_store_bytes(p.state)

    rows = []
    for name, entry in results.items():
        derived = {"rounds": entry["config"]["rounds"]}
        if "uplink_bytes_per_round" in entry:
            derived["uplink_bytes_per_round"] = \
                entry["uplink_bytes_per_round"]
        if "screened_per_round" in entry:
            derived["screened_per_round"] = entry["screened_per_round"]
        if "store_bytes" in entry:
            derived["store_bytes"] = entry["store_bytes"]
        if "robust_matrix" in entry:
            derived.update(entry["robust_matrix"])
        pair = _SPEEDUP_PAIRS.get(name)
        if pair and name in pair_ratio:
            speedup = pair_ratio[name]
            entry["config"][pair[1]] = round(speedup, 3)
            derived[pair[1]] = speedup
        rows.append(csv_row(f"round_engine/{name}", entry["us_per_round"],
                            derived))

    if out_path is not None and results:
        written = results
        if include is not None and out_path.exists():
            # subset runs (CI smoke) refresh their rows in place, keeping
            # the rest of the tracked baseline intact
            try:
                written = json.loads(out_path.read_text())
            except json.JSONDecodeError:
                written = {}
            written.update(results)
        validate_bench(written)
        out_path.write_text(json.dumps(written, indent=2, sort_keys=True)
                            + "\n")
    return rows
