"""Async FedDeper under stragglers: buffered aggregation vs sync rounds.

    PYTHONPATH=src python examples/async_feddeper.py

Scenario: 20 clients with heavy-tailed (lognormal) speeds on a non-i.i.d
shard split.  The synchronous server blocks every round on the slowest
sampled client; the buffered-async server (core/async_rounds.py)
aggregates as soon as ``buffer_size`` uploads arrive, discounting stale
ones by (1+s)^-alpha.  Both runs train FedDeper with identical
hyper-parameters; the comparison is *simulated wall-clock* to reach a
target test accuracy.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedDeper, SimConfig,
                        init_async_state, init_sim_state, make_async_round_fn,
                        make_global_eval, make_round_fn,
                        peek_sampled_clients)
from repro.data import make_federated_classification
from repro.models import classifier_loss, init_classifier

TARGET_ACC = 0.8


def main():
    cfg = MLP_MNIST
    ds = make_federated_classification(n_clients=20, per_client=200,
                                       split="shards", noise=2.5, seed=0)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    test = {k: jnp.asarray(v) for k, v in ds.test.items()}

    def apply_loss(p, b):
        return classifier_loss(cfg, p, b)

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
        return l, g

    eval_fn = make_global_eval(apply_loss, test)
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    x0 = init_classifier(cfg, jax.random.PRNGKey(42))
    acfg = AsyncSimConfig(n_clients=20, m_concurrent=8, buffer_size=4,
                          tau=5, batch_size=32, alpha=0.5, delay=10.0,
                          delay_dist="lognormal", delay_sigma=1.2, seed=1)
    delays = acfg.client_delays()
    print(f"client delays: mean={delays.mean():.1f} "
          f"max={delays.max():.1f} (lognormal stragglers)")

    # --- synchronous baseline; each round costs max(delay of sampled m)
    sim = SimConfig(n_clients=20, m_sampled=8, tau=5, batch_size=32, seed=1)
    state = init_sim_state(sim, strategy, x0)
    rf = make_round_fn(sim, strategy, grad_fn, data)
    t_sync, sync_time = 0.0, None
    for k in range(60):
        idx = np.asarray(peek_sampled_clients(state, sim))
        t_sync += float(delays[idx].max())
        state, _ = rf(state)
        acc = float(eval_fn(state)["test_acc"])
        if acc >= TARGET_ACC:
            sync_time = t_sync
            print(f"sync : round {k + 1:3d}  t={t_sync:8.1f}  acc={acc:.3f}")
            break
    if sync_time is None:
        print(f"sync : no target after 60 rounds (t={t_sync:.1f})")

    # --- buffered async
    state = init_async_state(acfg, strategy, x0)
    arf = make_async_round_fn(acfg, strategy, grad_fn, data)
    async_time = None
    for k in range(120):
        state, m = arf(state)
        acc = float(eval_fn(state)["test_acc"])
        if acc >= TARGET_ACC:
            async_time = m["sim_time"]
            print(f"async: aggr  {k + 1:3d}  t={async_time:8.1f}  "
                  f"acc={acc:.3f}  stale_max={m['staleness_max']:.0f}")
            break
    if async_time is None:
        print("async: no target after 120 aggregations")

    if sync_time and async_time:
        print(f"speedup (simulated time-to-{TARGET_ACC:.0%}): "
              f"{sync_time / async_time:.2f}x")


if __name__ == "__main__":
    main()
