"""End-to-end driver: train a ~100M-param LM with FedDeper rounds for a few
hundred steps (the datacenter regime on a reduced mesh).

    PYTHONPATH=src python examples/datacenter_feddeper.py --rounds 200

Uses the xlstm-125m architecture at a trimmed width so a few hundred
rounds finish on CPU; every round is the REAL round_step (tau local
alternating-SGD steps per client group + one cross-client delta mean) --
the same function the 512-chip dry-run lowers.  Loss on the skewed client
streams should drop from ~ln(V) as the model learns per-client unigram
structure.
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FedDeper, make_round_step
from repro.data import lm_client_batch
from repro.models import init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    args = ap.parse_args()

    cfg = get_config("xlstm-125m")
    cfg = dataclasses.replace(cfg, d_model=128, num_heads=4,
                              num_repeats=2, vocab_size=args.vocab)
    strat = FedDeper(eta=0.02, rho=0.004, lam=0.5)
    rng = jax.random.PRNGKey(0)
    x = init_model(cfg, rng)
    n_params = sum(l.size for l in jax.tree.leaves(x))
    print(f"arch={cfg.name} trimmed params={n_params:,} "
          f"clients={args.clients} tau={args.tau}")

    C = args.clients
    cs = jax.tree.map(lambda l: jnp.broadcast_to(l, (C,) + l.shape).copy(),
                      strat.client_init(x))
    step = jax.jit(make_round_step(cfg, strat))

    def batch_for(k):
        per = [lm_client_batch(vocab=cfg.vocab_size, n_clients=C, client=c,
                               round_k=k, tau=args.tau, batch=args.batch,
                               seq_len=args.seq, seed=0)
               for c in range(C)]
        return {key: jnp.asarray(np.stack([p[key] for p in per]))
                for key in per[0]}

    t0 = time.time()
    for k in range(args.rounds):
        x, _, cs, metrics = step(x, {}, cs, batch_for(k))
        if (k + 1) % 20 == 0 or k == 0:
            print(json.dumps({
                "round": k + 1,
                "global_loss": round(float(metrics["local_loss"]), 4),
                "personal_loss": round(float(metrics["personal_loss"]), 4),
                "elapsed_s": round(time.time() - t0, 1)}), flush=True)
    print("done; loss should be well below ln(V) =",
          round(float(jnp.log(jnp.float32(cfg.vocab_size))), 3))


if __name__ == "__main__":
    main()
