"""Quickstart for the serving tier (repro.serve, DESIGN.md §13).

Train, then serve the checkpoint through the slot-cache engine:

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \\
        --reduced --rounds 3 --ckpt-dir /tmp/run1
    PYTHONPATH=src python examples/serve_decode.py --ckpt-dir /tmp/run1

Without --ckpt-dir it serves fresh init weights (pure smoke).  The
example drives the library API directly -- weight source, ServeEngine,
request simulator; `python -m repro.launch.serve` is the full CLI with
the same knobs (and `--weights q8:ckpt:DIR` for int8 serving).
"""
import argparse
import json
import time

import numpy as np

from repro.configs import get_config
from repro.serve import ServeEngine, SimConfig, make_weight_source, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    help="decoder-only LM arch (reduced variant)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="launch/train.py checkpoint dir; default: init")
    ap.add_argument("--weights", default=None,
                    help="explicit source spec, e.g. q8:ckpt:/tmp/run1 "
                         "(overrides --ckpt-dir)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-tokens", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    spec = args.weights or (
        f"ckpt:{args.ckpt_dir}" if args.ckpt_dir else "init")
    cfg = get_config(args.arch).reduced()
    source = make_weight_source(spec)
    engine = ServeEngine(cfg, source.load(cfg), slots=args.slots,
                         max_len=args.max_len,
                         block_tokens=args.block_tokens)

    # one uniform batch: every slot decodes in jitted lax.scan blocks,
    # one host sync per block_tokens tokens
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(args.slots)]
    engine.generate(prompts, 2)  # compile (prefill bucket + block)
    t0 = time.time()
    gen = engine.generate(prompts, args.gen)
    dt = time.time() - t0

    # continuous batching: 2x oversubscribed requests, mixed prompt
    # lengths, staggered arrivals; finishing requests free slots for
    # the queue mid-flight
    metrics = simulate(engine, SimConfig(
        requests=2 * args.slots, prompt_lens=(4, 8, 12, 16),
        gen_tokens=args.gen, delay=0.01, seed=0))

    print(json.dumps({
        "arch": args.arch, "weights": source.name,
        "resident_mb": round(source.resident_bytes(cfg) / 2 ** 20, 2),
        "batch_decode_tok_s": round(gen.size / dt, 1),
        "block_compiles": engine.block_compile_count(),
        "sim_tokens_per_s": round(metrics["tokens_per_s"], 1),
        "sim_p50_ms": round(metrics["p50_ms"], 1),
        "sim_p99_ms": round(metrics["p99_ms"], 1),
        "first_request_tokens": gen[0, :8].tolist()}))


if __name__ == "__main__":
    main()
