"""Serve a small model with batched requests: prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-9b

Runs the reduced variant of any assigned arch (sliding-window ring
buffers, MLA latent caches, Mamba/xLSTM states all exercised by the same
serve_step the dry-run lowers at 32k/500k scale).
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.core import make_decode_step, make_prefill_step
from repro.models import init_cache, init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b", choices=ALL_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = init_model(cfg, rng)
    B, P, G = args.batch, args.prompt_len, args.gen
    batch = {"tokens": jax.random.randint(rng, (B, P), 0, cfg.vocab_size)}
    if cfg.frontend is not None:
        batch["frontend"] = 0.02 * jax.random.normal(
            rng, (B, cfg.frontend_tokens, cfg.d_model))
    cache = init_cache(cfg, B, P + G)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [tok]
    t0 = time.time()
    pos0 = P + (cfg.frontend_tokens if (cfg.frontend and not cfg.is_encdec)
                else 0)
    for i in range(G - 1):
        tok, _, cache = decode(params, cache, tok, jnp.int32(pos0 + i))
        toks.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(toks, axis=1)
    print(json.dumps({
        "arch": args.arch, "reduced_layers": cfg.num_layers,
        "batch": B, "decode_tok_s": round(B * (G - 1) / dt, 1),
        "first_request_tokens": gen[0].tolist()}))


if __name__ == "__main__":
    main()
