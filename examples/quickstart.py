"""Quickstart: FedDeper vs FedAvg on a synthetic non-i.i.d federated task.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core claim in ~a minute on CPU: under statistical
heterogeneity (pathological label shards), FedDeper's depersonalized
uploads converge faster than FedAvg at identical communication cost.
"""
import jax
import jax.numpy as jnp

from repro.configs.paper_models import MLP_MNIST
from repro.core import (FedAvg, FedDeper, SimConfig, init_sim_state,
                        make_global_eval, make_round_fn, run_rounds)
from repro.data import heterogeneity_stats, make_federated_classification
from repro.models import classifier_loss, init_classifier


def main():
    cfg = MLP_MNIST
    ds = make_federated_classification(n_clients=10, per_client=256,
                                       split="shards", noise=2.5, seed=0)
    print("client heterogeneity:", heterogeneity_stats(ds))
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    test = {k: jnp.asarray(v) for k, v in ds.test.items()}

    def apply_loss(p, b):
        return classifier_loss(cfg, p, b)

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
        return l, g

    eval_fn = make_global_eval(apply_loss, test)
    sim = SimConfig(n_clients=10, m_sampled=5, tau=10, batch_size=32,
                    seed=1)

    for strategy in (FedAvg(eta=0.05),
                     FedDeper(eta=0.05, rho=0.03, lam=0.5)):
        x0 = init_classifier(cfg, jax.random.PRNGKey(42))
        state = init_sim_state(sim, strategy, x0)
        rf = make_round_fn(sim, strategy, grad_fn, data)
        print(f"--- {strategy.name}")
        state, hist = run_rounds(
            state, rf, 50, eval_fn=eval_fn, eval_every=10,
            log=lambda r: print(r) if r["round"] % 10 == 0 else None)


if __name__ == "__main__":
    main()
