"""Reproduce the paper's Fig. 3 hyper-parameter study (rho, lambda, tau).

    PYTHONPATH=src python examples/hyperparameter_study.py
"""
import jax
import jax.numpy as jnp

from repro.configs.paper_models import MLP_MNIST
from repro.core import (FedDeper, SimConfig, init_sim_state, make_round_fn,
                        run_rounds)
from repro.data import make_federated_classification
from repro.models import classifier_loss, init_classifier


def main():
    cfg = MLP_MNIST
    ds = make_federated_classification(n_clients=10, per_client=256,
                                       split="shards", noise=2.5, seed=0)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(
            lambda q: classifier_loss(cfg, q, mb), has_aux=True)(p)
        return l, g

    def final_loss(strategy, tau=10, rounds=40):
        sim = SimConfig(10, 5, tau, 32, seed=1)
        st = init_sim_state(sim, strategy,
                            init_classifier(cfg, jax.random.PRNGKey(42)))
        rf = make_round_fn(sim, strategy, grad_fn, data)
        st, hist = run_rounds(st, rf, rounds)
        return sum(h["local_loss"] for h in hist[-5:]) / 5

    print("rho sweep (paper Fig. 3a): penalty must stay ~O(eta)")
    for rho in (0.0, 0.005, 0.03, 0.1, 0.5):
        print(f"  rho={rho:<6} loss={final_loss(FedDeper(eta=0.05, rho=rho, lam=0.5)):.4f}")
    print("lambda sweep (paper Fig. 3b), lambda in [1/2, 1]")
    for lam in (0.5, 0.65, 0.8, 1.0):
        print(f"  lam={lam:<6} loss={final_loss(FedDeper(eta=0.05, rho=0.03, lam=lam)):.4f}")
    print("tau sweep (paper Fig. 3c): extra local steps help at fixed K")
    for tau in (2, 5, 10, 20):
        print(f"  tau={tau:<6} loss={final_loss(FedDeper(eta=0.05, rho=0.03, lam=0.5), tau=tau):.4f}")


if __name__ == "__main__":
    main()
