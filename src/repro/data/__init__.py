from repro.data.synthetic import (  # noqa: F401
    FedDataset,
    heterogeneity_stats,
    lm_client_batch,
    make_federated_classification,
    make_federated_lm,
)
