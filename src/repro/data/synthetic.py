"""Seeded synthetic federated datasets (offline container: no downloads).

Classification (MNIST/CIFAR-like): class-prototype Gaussians, learnable by
MLP/CNN, federated by two non-i.i.d schemes:

  * ``shards``    -- McMahan et al. 2017 pathological split: sort by label,
                     deal each client ``shards_per_client`` label shards
                     (the paper's "non-i.i.d splits as (McMahan...)").
  * ``dirichlet`` -- per-client class mixture ~ Dir(alpha).

Personal test splits (Fig. 7) mix each client's own label distribution
with a fraction of common (global) samples, per the paper's setup.

LM streams: per-client skewed Markov token sources for the datacenter
regime (each mesh client group sees a different distribution -- the
statistical heterogeneity the technique targets).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class FedDataset:
    train: Dict[str, np.ndarray]          # per-client: x (n, Ni, ...), y (n, Ni)
    test: Dict[str, np.ndarray]           # global:     x (Nt, ...),    y (Nt,)
    personal_test: Dict[str, np.ndarray]  # per-client: x (n, Np, ...), y (n, Np)


def _make_pool(rng, input_shape, num_classes, n_samples, noise=0.6,
               sep=1.0):
    """Gaussian class-prototype pool.  Returns x (N, *shape), y (N,)."""
    protos = rng.normal(0, sep, size=(num_classes,) + tuple(input_shape))
    y = rng.integers(0, num_classes, size=(n_samples,))
    x = protos[y] + rng.normal(0, noise, size=(n_samples,) + tuple(input_shape))
    return x.astype(np.float32), y.astype(np.int32)


def _dirichlet_splits(rng, y, n_clients, alpha, per_client):
    num_classes = int(y.max()) + 1
    by_class = [np.flatnonzero(y == c) for c in range(num_classes)]
    for idxs in by_class:
        rng.shuffle(idxs)
    ptr = [0] * num_classes
    out = []
    for i in range(n_clients):
        mix = rng.dirichlet([alpha] * num_classes)
        counts = rng.multinomial(per_client, mix)
        sel = []
        for c, k in enumerate(counts):
            take = by_class[c][ptr[c]:ptr[c] + k]
            # wrap around if a class pool is exhausted (resample)
            if len(take) < k:
                extra = rng.choice(by_class[c], k - len(take))
                take = np.concatenate([take, extra])
            ptr[c] += k
            sel.append(take)
        sel = np.concatenate(sel) if sel else np.zeros((0,), np.int64)
        rng.shuffle(sel)
        out.append(sel[:per_client])
    return out


def _shard_splits(rng, y, n_clients, shards_per_client, per_client):
    order = np.argsort(y, kind="stable")
    n_shards = n_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    perm = rng.permutation(n_shards)
    out = []
    for i in range(n_clients):
        sel = np.concatenate([shards[s] for s in
                              perm[i * shards_per_client:
                                   (i + 1) * shards_per_client]])
        rng.shuffle(sel)
        if len(sel) < per_client:
            sel = np.concatenate([sel, rng.choice(sel, per_client - len(sel))])
        out.append(sel[:per_client])
    return out


def make_federated_classification(
        *, input_shape=(784,), num_classes=10, n_clients=10,
        per_client=500, test_size=2000, personal_test=64,
        split="shards", alpha=0.3, shards_per_client=2,
        common_frac=0.25, noise=0.6, seed=0) -> FedDataset:
    rng = np.random.default_rng(seed)
    pool_n = n_clients * per_client * 2 + test_size
    x, y = _make_pool(rng, input_shape, num_classes, pool_n, noise=noise)
    xt, yt = x[:test_size], y[:test_size]
    x, y = x[test_size:], y[test_size:]

    if split == "dirichlet":
        idxs = _dirichlet_splits(rng, y, n_clients, alpha, per_client)
    else:
        idxs = _shard_splits(rng, y, n_clients, shards_per_client, per_client)

    train = {
        "x": np.stack([x[i] for i in idxs]),
        "y": np.stack([y[i] for i in idxs]),
    }

    # personal test: (1-common_frac) from the client's own label dist +
    # common_frac common samples (paper: "a small number of common data")
    n_own = int(personal_test * (1 - common_frac))
    n_common = personal_test - n_own
    # class prototypes estimated from the global test split (same Gaussians)
    protos = np.stack([
        xt[yt == c].mean(0) if (yt == c).any() else np.zeros(input_shape)
        for c in range(num_classes)])
    px, py = [], []
    for i in range(n_clients):
        own_y = rng.choice(train["y"][i], n_own)  # client's label dist
        own_x = protos[own_y] + rng.normal(
            0, noise, size=(n_own,) + tuple(input_shape))
        com_sel = rng.integers(0, len(xt), n_common)
        px.append(np.concatenate([own_x.astype(np.float32), xt[com_sel]]))
        py.append(np.concatenate([own_y.astype(np.int32), yt[com_sel]]))

    return FedDataset(
        train=train,
        test={"x": xt, "y": yt},
        personal_test={"x": np.stack(px), "y": np.stack(py)},
    )


def heterogeneity_stats(ds: FedDataset) -> Dict[str, float]:
    """Quantify label skew: mean TV distance between client label dists
    and the global label dist (0 = iid)."""
    y = ds.train["y"]
    n_classes = int(y.max()) + 1
    glob = np.bincount(y.reshape(-1), minlength=n_classes) / y.size
    tv = []
    for i in range(y.shape[0]):
        ci = np.bincount(y[i], minlength=n_classes) / y[i].size
        tv.append(0.5 * np.abs(ci - glob).sum())
    return {"mean_tv": float(np.mean(tv)), "max_tv": float(np.max(tv))}


# ---------------------------------------------------------------------------
# LM token streams (datacenter regime)
# ---------------------------------------------------------------------------

def _client_unigram_probs(vocab: int, client: int, seed: int,
                          skew: float) -> np.ndarray:
    """Client-skewed Zipf unigram distribution: shared Zipf(1.1) base,
    client-specific head via a seeded permutation, sharpened by ``skew``."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    base = 1.0 / ranks ** 1.1
    perm_rng = np.random.default_rng(np.random.SeedSequence([seed, client]))
    probs = base[perm_rng.permutation(vocab)] ** skew
    return probs / probs.sum()


def lm_client_batch(*, vocab: int, n_clients: int, client: int, round_k: int,
                    tau: int, batch: int, seq_len: int, seed: int = 0,
                    skew: float = 2.0):
    """Deterministic per-(client, round) token batch with client-skewed
    unigram distributions (Zipf with client-specific permutation).

    Returns dict(tokens (tau, b, S), labels (tau, b, S)) as numpy."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, client, round_k]))
    probs = _client_unigram_probs(vocab, client, seed, skew)
    toks = rng.choice(vocab, size=(tau, batch, seq_len + 1), p=probs)
    return {"tokens": toks[..., :-1].astype(np.int32),
            "labels": toks[..., 1:].astype(np.int32)}


def make_federated_lm(*, vocab: int, n_clients: int, per_client: int,
                      seq_len: int, seed: int = 0, skew: float = 2.0):
    """Materialized per-client LM corpus for the buffered-async regime:
    same client-skewed Zipf unigrams as ``lm_client_batch`` but as fixed
    arrays {'tokens': (n, Ni, S), 'labels': (n, Ni, S)} so the async
    simulator can draw per-client minibatches by index."""
    out_t, out_l = [], []
    for c in range(n_clients):
        rng = np.random.default_rng(np.random.SeedSequence([seed, c, 0xF3D]))
        probs = _client_unigram_probs(vocab, c, seed, skew)
        toks = rng.choice(vocab, size=(per_client, seq_len + 1), p=probs)
        out_t.append(toks[..., :-1])
        out_l.append(toks[..., 1:])
    return {"tokens": np.stack(out_t).astype(np.int32),
            "labels": np.stack(out_l).astype(np.int32)}
