"""Continuous-batching greedy-decode engine on the flash-decode kernel.

One resident (slots, max_len) KV cache serves a rolling population of
requests: admitting a request prefills its prompt into a batch=1 row
cache and splices it into a free slot while the other slots keep their
state; decoding runs in jitted ``lax.scan`` blocks of ``block_tokens``
steps with ONE host sync per block (the emitted-token fetch), and the
cache buffer is donated through both the admit and the block step, so
the engine owns exactly one cache allocation for its whole life.

Per-row positions do the mixed-batch work: every slot carries its own
live length, the decode step writes each row's KV at its own ``lens[b]``
and masks attention at ``lens[b]+1`` (``kernels.ops.flash_decode``).
Inactive slots re-feed their last token with a frozen length; their
output is discarded and their cache row is fully overwritten on the next
admit, so they cost FLOPs but never correctness.

Compilation contract (pinned by tests/test_serve.py): the block step
compiles ONCE per engine regardless of how many blocks run, and admit
compiles once per prompt bucket (prompts pad to power-of-two buckets).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.serve.cache import init_slot_cache, write_slot


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 128,
                 block_tokens: int = 16, use_pallas: bool = True,
                 chunkwise: bool = True, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.block_tokens = block_tokens
        self.use_pallas = use_pallas
        self.chunkwise = chunkwise
        self.cache = init_slot_cache(cfg, slots, max_len, cache_dtype)
        self.lens = jnp.zeros((slots,), jnp.int32)
        self.tok = jnp.zeros((slots, 1), jnp.int32)
        self.active = np.zeros((slots,), bool)
        self._cache_dtype = cache_dtype
        self._prefill = jax.jit(self._prefill_fn)
        self._admit = jax.jit(self._admit_fn, donate_argnums=(0,))
        self._block = jax.jit(self._block_fn, donate_argnums=(1,))

    # -- jitted bodies ------------------------------------------------------

    def _prefill_fn(self, params, tokens, lens):
        """batch=1 prompt -> (first generated token (1,), row cache)."""
        row = init_slot_cache(self.cfg, 1, self.max_len, self._cache_dtype)
        logits, row = transformer.prefill(
            self.cfg, params, {"tokens": tokens}, row,
            chunkwise=self.chunkwise, use_pallas=self.use_pallas, lens=lens)
        return jnp.argmax(logits[:, 0], -1).astype(jnp.int32), row

    def _admit_fn(self, cache, row, slot, lens, tok, active, n, first):
        cache = write_slot(cache, row, slot)
        return (cache, lens.at[slot].set(n),
                tok.at[slot, 0].set(first[0]), active.at[slot].set(True))

    def _block_fn(self, params, cache, tok, lens, active):
        def step(carry, _):
            cache, tok, lens = carry
            logits, cache = transformer.decode_step(
                self.cfg, params, cache, tok, lens,
                chunkwise=self.chunkwise, use_pallas=self.use_pallas)
            nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            nxt = jnp.where(active, nxt, tok[:, 0]).reshape(-1, 1)
            lens = lens + active.astype(jnp.int32)
            return (cache, nxt, lens), nxt[:, 0]

        (cache, tok, lens), toks = jax.lax.scan(
            step, (cache, tok, lens), None, length=self.block_tokens)
        return cache, tok, lens, toks  # toks: (block_tokens, slots)

    # -- host API -----------------------------------------------------------

    @staticmethod
    def _bucket(n: int) -> int:
        return max(8, 1 << (n - 1).bit_length())

    def admit(self, slot: int, prompt) -> int:
        """Prefill ``prompt`` (1-D int tokens) into ``slot``.  Returns
        the first generated token (greedy, from the prefill logits) --
        the ONLY per-admit host sync."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n = prompt.shape[0]
        if not (0 < n <= self.max_len):
            raise ValueError(f"prompt length {n} vs max_len {self.max_len}")
        P = min(self._bucket(n), self.max_len)
        toks = np.zeros((1, P), np.int32)
        toks[0, :n] = prompt
        first, row = self._prefill(self.params, jnp.asarray(toks),
                                   jnp.full((1,), n, jnp.int32))
        # device-side active mask mirrors the host one lazily: it is only
        # read inside _block_fn, which receives it as an argument
        act = jnp.asarray(self.active)
        self.cache, self.lens, self.tok, act = self._admit(
            self.cache, row, slot, self.lens, self.tok, act,
            jnp.int32(n), first)
        self.active[slot] = True
        return int(first[0])

    def release(self, slot: int) -> None:
        self.active[slot] = False

    def run_block(self) -> np.ndarray:
        """Advance every slot ``block_tokens`` greedy steps.  Returns the
        emitted tokens (block_tokens, slots) -- one host sync."""
        self.cache, self.tok, self.lens, toks = self._block(
            self.params, self.cache, self.tok, self.lens,
            jnp.asarray(self.active))
        return np.asarray(toks)

    def block_compile_count(self) -> int:
        return self._block._cache_size()

    def generate(self, prompts, gen_tokens: int) -> np.ndarray:
        """Batch convenience: greedy-decode ``gen_tokens`` tokens for each
        prompt (len(prompts) <= slots).  Returns (B, gen_tokens) int32."""
        B = len(prompts)
        if B > self.slots:
            raise ValueError(f"{B} prompts > {self.slots} slots")
        firsts = [self.admit(i, prompts[i]) for i in range(B)]
        cols = [np.asarray(firsts, np.int32).reshape(B, 1)]
        need = gen_tokens - 1
        while need > 0:
            toks = self.run_block()  # (N, slots)
            cols.append(toks[:min(need, toks.shape[0]), :B].T)
            need -= toks.shape[0]
        for i in range(B):
            self.release(i)
        return np.concatenate(cols, axis=1)[:, :gen_tokens]
