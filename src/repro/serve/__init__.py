"""Production serving tier: weight sources, slot KV caches, a continuous
-batching engine on the Pallas flash-decode kernel, and a request
simulator (DESIGN.md §13).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \\
      --ckpt-dir runs/ckpt --gen-tokens 32
"""
from repro.serve.cache import init_slot_cache, read_slot, write_slot  # noqa: F401
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.simulator import SimConfig, simulate  # noqa: F401
from repro.serve.weights import WeightSource, make_weight_source  # noqa: F401
