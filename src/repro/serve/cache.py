"""Slot KV caches: one resident batch cache, per-slot insert/extract.

``models.init_cache`` trees have two top-level groups with different
batch axes:

  * ``prefix``  -- per-layer caches, leaves (B, L, K, D): batch axis 0;
  * ``pattern`` -- lax.scan-stacked caches, leaves (R, B, L, K, D):
    batch axis 1 (the repeat dim leads).

The engine keeps ONE (slots, max_len, ...) cache alive across requests
and splices a freshly-prefilled single-row cache into a slot when a new
request is admitted (continuous batching: other slots keep decoding,
their rows are untouched).  All three helpers are pure pytree ops, so
they fuse into the callers' jitted steps.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def init_slot_cache(cfg, slots: int, max_len: int, dtype=jnp.float32):
    from repro.models import init_cache
    return init_cache(cfg, slots, max_len, dtype)


def _splice(axis, dst, src, slot):
    return jax.lax.dynamic_update_slice_in_dim(
        dst, src.astype(dst.dtype), slot, axis=axis)


def write_slot(cache: Pytree, row: Pytree, slot) -> Pytree:
    """Insert a batch=1 cache ``row`` into batch position ``slot``."""
    return {
        "prefix": jax.tree.map(
            lambda c, r: _splice(0, c, r, slot),
            cache["prefix"], row["prefix"]),
        "pattern": jax.tree.map(
            lambda c, r: _splice(1, c, r, slot),
            cache["pattern"], row["pattern"]),
    }


def read_slot(cache: Pytree, slot) -> Pytree:
    """Extract batch position ``slot`` as a batch=1 cache row."""
    return {
        "prefix": jax.tree.map(
            lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=0),
            cache["prefix"]),
        "pattern": jax.tree.map(
            lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
            cache["pattern"]),
    }
