"""Batched request simulator: concurrent users against a ServeEngine.

Requests arrive on a Poisson-like schedule (the async tier's delay
distributions: constant / uniform / mean-normalized lognormal, drawn
once from a ``SeedSequence`` so runs are reproducible), carry mixed
prompt lengths (cycled from ``prompt_lens``), and are admitted into free
engine slots as they arrive -- continuous batching: a finishing request
frees its slot mid-flight and the next arrival reuses it while the other
slots keep decoding.

The clock is hybrid wall/sim: by default each admit/block charges its
MEASURED wall seconds (real latencies); with ``time_unit > 0`` every
token instead costs exactly ``time_unit`` simulated seconds, making the
whole trace deterministic (CI smoke).  When all slots idle the clock
fast-forwards to the next arrival instead of sleeping.

``simulate`` returns the per-request records plus the aggregate numbers
``BENCH_serve.json`` tracks: tokens/s, p50/p99 latency, generated count.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class SimConfig:
    requests: int = 8
    prompt_lens: Tuple[int, ...] = (4, 8, 12, 16)
    gen_tokens: int = 32
    delay: float = 0.0       # mean inter-arrival gap (seconds); 0 = burst
    delay_dist: str = "lognormal"  # 'constant' | 'uniform' | 'lognormal'
    delay_sigma: float = 1.0
    seed: int = 0
    time_unit: float = 0.0   # >0: seconds per token, deterministic clock

    def arrivals(self) -> np.ndarray:
        """Cumulative arrival times, one per request (seconds)."""
        if self.delay <= 0:
            return np.zeros(self.requests)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 0x5E83]))
        if self.delay_dist == "constant":
            gaps = np.full(self.requests, float(self.delay))
        elif self.delay_dist == "uniform":
            gaps = rng.uniform(0.0, 2.0 * self.delay, self.requests)
        elif self.delay_dist == "lognormal":
            gaps = self.delay * rng.lognormal(
                -0.5 * self.delay_sigma ** 2, self.delay_sigma,
                self.requests)
        else:
            raise ValueError(f"unknown delay_dist {self.delay_dist!r}")
        return np.cumsum(gaps) - gaps[0]  # first request at t=0


@dataclass
class _Request:
    rid: int
    arrival: float
    prompt: np.ndarray
    started: float = -1.0
    finished: float = -1.0
    emitted: int = 0
    tokens: list = field(default_factory=list)


def simulate(engine, sim: SimConfig, *, vocab: Optional[int] = None):
    """Run ``sim.requests`` requests through ``engine``; returns metrics."""
    vocab = vocab or engine.cfg.vocab_size
    rng = np.random.default_rng(np.random.SeedSequence([sim.seed, 0x9E0]))
    arrivals = sim.arrivals()
    pending = deque(
        _Request(i, float(arrivals[i]),
                 rng.integers(0, vocab,
                              sim.prompt_lens[i % len(sim.prompt_lens)],
                              dtype=np.int64).astype(np.int32))
        for i in range(sim.requests))
    in_slot: dict = {}
    free = list(range(engine.slots))
    clock = 0.0
    tokens_total = 0
    done = []

    def charge(wall_s: float, tokens: int) -> float:
        return tokens * sim.time_unit if sim.time_unit > 0 else wall_s

    while pending or in_slot:
        # admit every arrived request that has a free slot
        while free and pending and pending[0].arrival <= clock:
            req = pending.popleft()
            slot = free.pop(0)
            t0 = time.perf_counter()
            first = engine.admit(slot, req.prompt)
            clock += charge(time.perf_counter() - t0, len(req.prompt) + 1)
            req.started = clock
            req.emitted = 1
            req.tokens.append(first)
            tokens_total += 1
            in_slot[slot] = req
            if req.emitted >= sim.gen_tokens:  # degenerate gen_tokens=1
                req.finished = clock
                engine.release(slot)
                done.append(in_slot.pop(slot))
                free.append(slot)
        if not in_slot:
            if pending:  # idle: fast-forward to the next arrival
                clock = max(clock, pending[0].arrival)
                continue
            break
        t0 = time.perf_counter()
        toks = engine.run_block()  # (block_tokens, slots)
        clock += charge(time.perf_counter() - t0, toks.shape[0])
        for slot, req in list(in_slot.items()):
            take = min(sim.gen_tokens - req.emitted, toks.shape[0])
            req.tokens.extend(int(t) for t in toks[:take, slot])
            req.emitted += take
            tokens_total += take
            if req.emitted >= sim.gen_tokens:
                req.finished = clock
                engine.release(slot)
                done.append(in_slot.pop(slot))
                free.append(slot)

    lat = np.array([r.finished - r.arrival for r in done])
    total_s = max(clock, 1e-9)
    return {
        "requests": len(done),
        "generated": int(tokens_total),
        "total_s": float(total_s),
        "tokens_per_s": float(tokens_total / total_s),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "per_request": [
            {"rid": r.rid, "arrival_s": round(r.arrival, 6),
             "latency_s": round(r.finished - r.arrival, 6),
             "prompt_len": int(r.prompt.shape[0]),
             "generated": r.emitted}
            for r in sorted(done, key=lambda r: r.rid)],
    }
