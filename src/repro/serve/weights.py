"""Weight sources: where the served model's parameters come from.

``make_weight_source`` parses the ``--weights`` mini-language (same
``configs.specs`` machinery as ``--store``/``--compress``):

    init[:SEED]        fresh ``init_model`` weights (smoke tests)
    ckpt:DIR           member 0 (the dense global model) of the latest
                       training checkpoint in DIR -- the train->serve
                       handoff; works for every ``--store`` layout
                       because the global model is always dense
    q8:<source>        int8-quantize the inner source's weights at load
    fp8:<source>       float8_e4m3fn-quantize the inner source's weights

Quantized sources reuse the comm tier's kernels (``kernels/quantize.py``
via ``kernels.ops``): each leaf is normalized by its own ``amax/qmax``
scale, packed into one ``(rows, LANES)`` buffer, and rounded with the
SAME pack kernel the q8 compressor uses -- with the uniform draw pinned
to 0.5, i.e. deterministic round-half-up, so serving is reproducible.
The resident form is the int8 buffer + per-leaf f32 scales
(``resident_bytes`` counts exactly that); ``load`` dequantizes back to
the leaf dtypes for compute.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.specs import SpecError, cast_value, parse_spec

Pytree = Any


class WeightSource:
    """A named recipe producing the served parameter tree."""

    name: str = "?"

    def load(self, cfg) -> Pytree:  # pragma: no cover - interface
        raise NotImplementedError

    def resident_bytes(self, cfg) -> int:
        """Bytes the source keeps resident to be able to serve."""
        shapes = _param_shapes(cfg)
        return sum(math.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(shapes))


def _param_shapes(cfg):
    from repro.models.transformer import param_shapes
    return param_shapes(cfg)


@dataclass(frozen=True)
class InitSource(WeightSource):
    seed: int = 0

    @property
    def name(self) -> str:
        return f"init:{self.seed}"

    def load(self, cfg) -> Pytree:
        from repro.models import init_model
        return init_model(cfg, jax.random.PRNGKey(self.seed))


@dataclass(frozen=True)
class CheckpointSource(WeightSource):
    directory: str

    @property
    def name(self) -> str:
        return f"ckpt:{self.directory}"

    def load(self, cfg) -> Pytree:
        from repro.checkpoint import latest_checkpoint, restore_subtree
        from repro.models.transformer import param_shapes
        path = latest_checkpoint(self.directory)
        if path is None:
            raise SystemExit(
                f"--weights {self.name}: no checkpoint found in "
                f"{self.directory!r} (expected ckpt_XXXXXXXX.npz from "
                "repro.launch.train --ckpt-dir)")
        params, _ = restore_subtree(path, param_shapes(cfg), index=0)
        return params


@dataclass(frozen=True)
class QuantizedSource(WeightSource):
    """Serve the inner source's weights through the comm tier's
    quantizer: per-leaf amax/qmax scales, one packed pack-kernel launch,
    deterministic round-half-up (rand pinned to 0.5)."""

    inner: WeightSource
    mode: str = "int8"  # 'int8' | 'fp8'

    @property
    def name(self) -> str:
        tag = "q8" if self.mode == "int8" else "fp8"
        return f"{tag}:{self.inner.name}"

    def _quantize(self, params):
        from repro.kernels.ops import dequantize, quantize_stochastic
        from repro.kernels.tiling import TreeFlattener
        qmax = 127.0 if self.mode == "int8" else 448.0  # e4m3fn max
        f32 = jax.tree.map(lambda t: t.astype(jnp.float32), params)
        scales = jax.tree.map(
            lambda t: jnp.maximum(jnp.max(jnp.abs(t)), 1e-30) / qmax, f32)
        normed = jax.tree.map(jnp.divide, f32, scales)
        fl = TreeFlattener(f32)
        buf = fl.flatten(normed)
        if self.mode == "int8":
            packed = quantize_stochastic(buf, jnp.full_like(buf, 0.5))
            deq = dequantize(packed)
        else:
            packed = buf.astype(jnp.float8_e4m3fn)
            deq = packed.astype(jnp.float32)
        return packed, scales, fl, deq

    def load(self, cfg) -> Pytree:
        params = self.inner.load(cfg)
        _, scales, fl, deq = self._quantize(params)
        dense = jax.tree.map(jnp.multiply, fl.unflatten(deq), scales)
        return jax.tree.map(lambda d, p: d.astype(p.dtype), dense, params)

    def resident_bytes(self, cfg) -> int:
        shapes = _param_shapes(cfg)
        leaves = jax.tree.leaves(shapes)
        n = sum(math.prod(l.shape) for l in leaves)
        return n * 1 + len(leaves) * 4  # 1 byte/elem + f32 scale per leaf


def make_weight_source(spec: Optional[str]) -> WeightSource:
    """``init[:SEED] | ckpt:DIR | q8:<source> | fp8:<source>``."""
    if spec is None or spec == "":
        return InitSource(0)
    parsed = parse_spec(
        spec, flag="--weights",
        heads=("init", "ckpt", "q8", "fp8"),
        arity={"init": (0, 1), "ckpt": (1, 1), "q8": (0, 1), "fp8": (0, 1)},
        greedy=("ckpt", "q8", "fp8"),
        head_label="source",
        head_hint="(grammar: init[:SEED] | ckpt:DIR | q8[:SRC] | "
                  "fp8[:SRC])")
    if parsed.head == "init":
        seed = cast_value("--weights", "seed", parsed.args[0], int) \
            if parsed.args else 0
        return InitSource(seed)
    if parsed.head == "ckpt":
        return CheckpointSource(parsed.args[0])
    inner = make_weight_source(parsed.args[0] if parsed.args else "init")
    if isinstance(inner, QuantizedSource):
        raise SpecError(
            f"--weights: nested quantization {spec!r} is not supported")
    return QuantizedSource(inner, "int8" if parsed.head == "q8" else "fp8")
