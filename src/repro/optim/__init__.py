from repro.optim.optimizers import (  # noqa: F401
    adamw,
    cosine_schedule,
    linear_warmup,
    sgd,
)
