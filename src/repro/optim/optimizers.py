"""Functional optimizers (no optax in the container -- built from scratch).

API (optax-like):  opt = sgd(...); state = opt.init(params);
                   params, state = opt.update(grads, state, params, lr)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple]


def sgd(momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p,
                                 grads, params)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p - lr * g).astype(p.dtype), params, grads)
            return new_params, state
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        step = jax.tree.map(lambda m, g: momentum * m + g, mu, grads) \
            if nesterov else mu
        new_params = jax.tree.map(
            lambda p, s: (p - lr * s).astype(p.dtype), params, step)
        return new_params, {"mu": mu}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(
            jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new_params = jax.tree.map(
            lambda p, mi, vi: (p - lr * (mi / bc1 /
                                         (jnp.sqrt(vi / bc2) + eps)
                                         + weight_decay * p)).astype(p.dtype),
            params, m, v)
        return new_params, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def linear_warmup(base_lr: float, warmup_steps: int):
    def schedule(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1),
                           1.0)
        return base_lr * frac

    return schedule


def cosine_schedule(base_lr: float, total_steps: int,
                    warmup_steps: int = 0, min_frac: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return base_lr * warm * cos

    return schedule
