"""Pytree checkpointing to .npz with path-flattened keys + json metadata.

Handles arbitrary nested dict/list/tuple/NamedTuple pytrees (the treedef is
serialized via jax.tree_util key paths and rebuilt on restore against a
template pytree).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np
import jax.numpy as jnp

Pytree = Any


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no native bf16
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    metadata: Optional[dict] = None) -> str:
    """Atomic write: the full archive lands in ``<path>.tmp.npz``, is
    fsync'd, and only then renamed over the final name (``os.replace``
    is atomic on POSIX) -- a kill at ANY point leaves either the
    complete previous checkpoint or the complete new one, never a
    loadable-but-truncated file; ``latest_checkpoint`` never matches the
    tmp name."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # .npz suffix stops np.savez appending another
    flat = _flatten(tree)
    meta = json.dumps({"step": step, **(metadata or {})})
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(meta.encode(), np.uint8),
                     **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        # a failed/killed write must not leave a stale tmp that a later
        # save could trip over
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass
        raise
    return path


def restore_checkpoint(path: str, template: Pytree) -> tuple:
    """Restore into the structure of ``template``.  Returns (tree, meta)."""
    with np.load(path) as data:
        meta = json.loads(bytes(data["__meta__"]).decode()) \
            if "__meta__" in data else {}
        flat = {k: data[k] for k in data.files if k != "__meta__"}
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for (path_keys, leaf_t) in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf_t.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {leaf_t.shape}")
        # cast via jnp: handles bf16 and other ml_dtypes targets
        leaves.append(jnp.asarray(arr).astype(leaf_t.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", name)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, name), int(m.group(1))
    return best
