"""Pytree checkpointing to .npz with path-flattened keys + json metadata.

Handles arbitrary nested dict/list/tuple/NamedTuple pytrees (the treedef is
serialized via jax.tree_util key paths and rebuilt on restore against a
template pytree).

Virtual client stores (``core.store.VirtualStore`` leaves) are NEVER
densified: their backing-tier rows go to a per-checkpoint sidecar
directory (``ckpt_XXXXXXXX.stores/<key>/``) as atomic shard files --
written BEFORE the main npz so the npz ``os.replace`` stays the single
commit point -- and the npz itself carries only a ``__vstore__/<key>``
layout-meta marker.  Restore loads the shards back into the template's
store objects and fails fast when the checkpoint's store layout does not
match the template's (resuming under a different ``--store`` spec).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np
import jax.numpy as jnp

Pytree = Any

_VSTORE_PREFIX = "__vstore__/"


def _is_vstore(leaf) -> bool:
    return hasattr(leaf, "save_rows") and hasattr(leaf, "meta_dict")


def _path_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _store_sidecar(path: str) -> str:
    """``.../ckpt_00000012.npz`` -> ``.../ckpt_00000012.stores`` (per-step
    named: a crash while writing step T's sidecar leaves step T-1's
    checkpoint and sidecar untouched)."""
    base = path[:-len(".npz")] if path.endswith(".npz") else path
    return base + ".stores"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _path_key(path)
        if _is_vstore(leaf):
            continue  # save_checkpoint routes these to the sidecar
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no native bf16
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    metadata: Optional[dict] = None) -> str:
    """Atomic write: the full archive lands in ``<path>.tmp.npz``, is
    fsync'd, and only then renamed over the final name (``os.replace``
    is atomic on POSIX) -- a kill at ANY point leaves either the
    complete previous checkpoint or the complete new one, never a
    loadable-but-truncated file; ``latest_checkpoint`` never matches the
    tmp name.

    Virtual-store leaves write their rows to the checkpoint's sidecar
    dir as atomic shards (``VirtualStore.save_rows``) FIRST; the npz
    replace then commits the whole checkpoint."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # .npz suffix stops np.savez appending another
    flat = _flatten(tree)
    vstores = {
        _path_key(p): leaf
        for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
        if _is_vstore(leaf)
    }
    for key, store in vstores.items():
        store.save_rows(os.path.join(_store_sidecar(path),
                                     key.replace("/", "_")))
        marker = json.dumps(store.meta_dict())
        flat[_VSTORE_PREFIX + key] = np.frombuffer(marker.encode(),
                                                   np.uint8)
    meta = json.dumps({"step": step, **(metadata or {})})
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(meta.encode(), np.uint8),
                     **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        # a failed/killed write must not leave a stale tmp that a later
        # save could trip over
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass
        raise
    return path


def restore_checkpoint(path: str, template: Pytree) -> tuple:
    """Restore into the structure of ``template``.  Returns (tree, meta).

    A virtual-store template leaf loads its rows from the checkpoint's
    sidecar dir (in place; the same store object is returned in the
    tree).  Mixing layouts fails fast: a dense checkpoint cannot restore
    into a virtual template or vice versa -- rerun with the ``--store``
    spec the checkpoint was written under."""
    with np.load(path) as data:
        meta = json.loads(bytes(data["__meta__"]).decode()) \
            if "__meta__" in data else {}
        flat = {k: data[k] for k in data.files if k != "__meta__"}
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for (path_keys, leaf_t) in paths:
        key = _path_key(path_keys)
        if _is_vstore(leaf_t):
            if _VSTORE_PREFIX + key not in flat:
                raise ValueError(
                    f"checkpoint stores DENSE rows for {key!r} but this "
                    "run uses a virtual store layout -- restore with the "
                    "--store spec the checkpoint was written with")
            leaf_t.load_rows(os.path.join(_store_sidecar(path),
                                          key.replace("/", "_")))
            leaves.append(leaf_t)
            continue
        if key not in flat:
            # a dense template leaf "clients/b" hits a virtual ckpt whose
            # marker sits at the store root, "__vstore__/clients"
            marked = any(
                k.startswith(_VSTORE_PREFIX)
                and key.startswith(k[len(_VSTORE_PREFIX):])
                for k in flat)
            if marked:
                raise ValueError(
                    f"checkpoint stores VIRTUAL rows for {key!r} but "
                    "this run uses the dense store layout -- restore "
                    "with the --store spec the checkpoint was written "
                    "with")
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf_t.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {leaf_t.shape}")
        # cast via jnp: handles bf16 and other ml_dtypes targets
        leaves.append(jnp.asarray(arr).astype(leaf_t.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def restore_subtree(path: str, template: Pytree, index: int = 0) -> tuple:
    """Restore ONE top-level member of a checkpointed tuple-tree into
    ``template``.  Returns (subtree, meta).

    Training checkpoints store ``_ckpt_tree`` tuples whose member 0 is
    the dense global model -- the serve tier restores just that slice
    against a freshly-inited parameter template, without reconstructing
    client state (which may live in virtual-store sidecars; the global
    model is always dense, so this works for every ``--store`` layout)."""
    prefix = f"{index}/"
    with np.load(path) as data:
        meta = json.loads(bytes(data["__meta__"]).decode()) \
            if "__meta__" in data else {}
        flat = {k[len(prefix):]: data[k] for k in data.files
                if k.startswith(prefix)}
    if not flat:
        raise KeyError(f"checkpoint {path} has no leaves under {prefix!r}")
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_flatten(template)[1]
    leaves = []
    for path_keys, leaf_t in paths:
        key = _path_key(path_keys)
        if key not in flat:
            raise KeyError(f"checkpoint member {index} missing leaf "
                           f"{key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf_t.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {leaf_t.shape} -- was the checkpoint written "
                "with a different --arch/--reduced?")
        leaves.append(jnp.asarray(arr).astype(leaf_t.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", name)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, name), int(m.group(1))
    return best
