from repro.checkpoint.npz import (  # noqa: F401
    latest_checkpoint,
    restore_checkpoint,
    restore_subtree,
    save_checkpoint,
)
