"""Shims over jax API renames so one codebase runs on the pinned
container jax (0.4.x) and on current releases.

  * ``shard_map``      -- ``jax.shard_map`` (>=0.5) vs
                          ``jax.experimental.shard_map.shard_map``
  * ``make_mesh``      -- ``axis_types=`` kwarg only exists on >=0.5;
                          0.4.x meshes are implicitly all-auto
  * ``axis_types_auto``-- ``jax.sharding.AxisType.Auto`` tuple, or None
  * ``set_mesh``       -- ``jax.set_mesh`` vs entering the Mesh itself
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04x

    def shard_map(f, mesh=None, **kw):
        if mesh is None:
            # new-style ambient mesh (set by `with mesh:` / set_mesh)
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
            if mesh.empty:
                raise ValueError("shard_map: mesh=None requires an "
                                 "ambient mesh context")
        return _shard_map_04x(f, mesh=mesh, **kw)


def axis_size(name):
    """``jax.lax.axis_size`` (>=0.5); 0.4.x spells it psum(1, name),
    which constant-folds to the mesh axis size."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def axis_types_auto(n: int):
    """(AxisType.Auto,) * n where AxisType exists; None on 0.4.x (whose
    meshes are always auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return None if axis_type is None else (axis_type.Auto,) * n


def make_mesh(axis_shapes, axis_names, *, axis_types=None):
    if axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # 0.4.x: Mesh is its own context manager
