"""Federated-optimization strategies: FedDeper (the paper) + baselines.

Every strategy is a frozen dataclass of hyper-parameters with pure-pytree
methods, so the same code runs in both regimes:

  * simulation  -- ``jax.vmap`` over a leading client dim on one device
                   (paper reproduction, n in {10, 100});
  * datacenter  -- client dim sharded over a mesh axis ('data' single-pod /
                   'pod' multi-pod); the delta-mean in ``aggregate`` is the
                   one cross-client all-reduce per round (tau local steps of
                   zero cross-client traffic).

Protocol (all pytrees are params-shaped unless noted):

  client_init(x)   -> per-client state
  server_init(x)   -> server state
  broadcast(x, ss) -> ctx sent to clients this round (SCAFFOLD's c)
  local_round(x, ctx, cs, batches, grad_fn)
                   -> (new_cs, upload, metrics);  ``batches`` is a pytree
                      stacked over a leading tau axis, scanned.
  aggregate(x, ss, uploads, p, weights=None, mean_fn=None)
                   -> (new_x, new_ss, metrics); ``uploads`` stacked over
                      the sampled-client axis.  ``weights`` (optional,
                      (m,)) are per-upload aggregation weights -- the
                      async regime's staleness discounts; None keeps the
                      uniform mean.  ``mean_fn`` (optional) replaces the
                      tree mean over the cohort axis wholesale -- the
                      mesh placement passes the mean that lowers to the
                      round's single cross-client ``psum`` under
                      shard_map.  The two compose: when both are given,
                      ``mean_fn(tree, weights=w)`` must lower the
                      weighted mean into that same collective
                      (``engine._psum_mean_fn`` does).  Contract: an
                      aggregate calls ``mean_fn`` EXACTLY ONCE on one
                      tree containing every upload leaf (Scaffold means
                      its whole {dv, dc} dict in one call), so one round
                      = one collective.  Overrides must accept both
                      kwargs.

``grad_fn(params, minibatch) -> (loss, grads)``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
GradFn = Callable[[Pytree, Pytree], Tuple[jax.Array, Pytree]]


def tmap(f, *trees):
    return jax.tree.map(f, *trees)


def _axpy(a: float, x: Pytree, y: Pytree) -> Pytree:
    """y + a * x elementwise over pytrees (x upcast to y's dtype: fp8
    uploads have no implicit promotion path)."""
    return tmap(lambda xi, yi:
                (yi + a * xi.astype(yi.dtype)).astype(yi.dtype), x, y)


def tree_mean0(tree: Pytree) -> Pytree:
    return tmap(lambda t: t.mean(0), tree)


def tree_weighted_mean(tree: Pytree, w: jax.Array) -> Pytree:
    """Weighted mean over the leading (client) axis: sum_i w_i t_i / sum_i
    w_i.  Computed in float32 -- uploads may be low-precision (fp8) and the
    weights are the async regime's staleness discounts.

    Zero-weight-sum guard: all-zero weights (every buffered upload
    discounted to nothing) fall back to the uniform mean instead of
    dividing by zero; any positive sum is divided through unchanged."""
    w = jnp.asarray(w, jnp.float32)
    s = w.sum()
    safe = jnp.where(s > 0, s, 1.0)
    wn = jnp.where(s > 0, w / safe, 1.0 / w.shape[0])
    return tmap(lambda t: jnp.tensordot(wn, t.astype(jnp.float32),
                                        axes=(0, 0)), tree)


class LocalWeights:
    """A SHARD-LOCAL weight vector for the mesh placement's screened
    aggregation: ``w`` holds only this shard's cohort lanes (length
    m / axis_size under shard_map), ``m`` is the GLOBAL cohort size.

    The replicated-weights path (``weights`` as a plain (m,) array, the
    async staleness discounts) normalizes shard-locally because every
    shard holds the full vector.  Screening weights are born per-lane
    INSIDE the shard (``faults.screen_upload``), so no shard knows the
    global weight sum up front -- ``engine._psum_mean_fn`` bundles the
    local sum into the round's single psum and records the global sum
    here (``set_global_sum``) for Scaffold's weight-normalized
    participation.  Deliberately NOT a pytree node: it rides kwargs, not
    operands."""

    __slots__ = ("w", "m", "_sum")

    def __init__(self, w: jax.Array, m: int):
        self.w = jnp.asarray(w, jnp.float32)
        self.m = int(m)
        self._sum = None

    def set_global_sum(self, s: jax.Array) -> None:
        self._sum = s

    def global_sum(self) -> jax.Array:
        if self._sum is not None:
            return self._sum
        return self.w.sum()  # 1-shard case: local IS global


def weight_mass(weights) -> Tuple[jax.Array, int]:
    """``(sum of weights, cohort size m)`` for either weights flavor --
    the two numbers Scaffold's p_eff participation scaling needs.  Plain
    (m,) arrays (replicated staleness discounts) sum shard-locally;
    ``LocalWeights`` answers with the psum-reduced global sum."""
    if isinstance(weights, LocalWeights):
        return weights.global_sum(), weights.m
    w = jnp.asarray(weights, jnp.float32)
    return w.sum(), w.shape[0]


def resolve_mean(mean_fn, weights):
    """The cohort mean an ``aggregate`` reduces its uploads with: the
    caller-supplied ``mean_fn`` when given (the mesh placement's
    psum-lowering mean), else the plain / staleness-weighted tree mean.
    The two knobs COMPOSE: a ``mean_fn`` must accept an optional
    ``weights`` kwarg and lower the weighted mean into its own collective
    (``engine._psum_mean_fn`` rides the weighted partial sums on the
    round's single psum), so staleness-discounted aggregation stays a
    one-collective round on the mesh.  ``mean_fn`` without ``weights``
    is called with no kwarg at all -- the uniform mesh path stays
    bit-for-bit what it was.

    ``weights`` may also be a ``LocalWeights`` (the mesh placement's
    shard-local screening weights): with a ``mean_fn`` it is passed
    through whole (``_psum_mean_fn`` owns the partial-sum + psum
    lowering); without one (the vmap placement never builds it, but unit
    tests may) the raw vector feeds the plain weighted mean."""
    if mean_fn is not None:
        if weights is not None:
            return lambda tree: mean_fn(tree, weights=weights)
        return mean_fn
    if weights is None:
        return tree_mean0
    if isinstance(weights, LocalWeights):
        return lambda tree: tree_weighted_mean(tree, weights.w)
    return lambda tree: tree_weighted_mean(tree, weights)


def twin_grad_fn(loss_fn: Callable[[Pytree, Pytree], Tuple[jax.Array, Any]]
                 ) -> GradFn:
    """Build a ``grad_fn`` from a differentiable ``loss_fn(params, batch)
    -> (loss, aux)`` that also carries a ``.twin`` attribute evaluating
    BOTH FedDeper streams in ONE joint forward/backward:

        twin(y, v, mb) -> (loss_y, grad_y, loss_v, grad_v)

    differentiating ``loss(y) + loss(v)`` w.r.t. the stacked ``(y, v)``
    pair.  The cross-terms are identically zero, so the gradients equal
    two separate ``grad_fn`` calls (bitwise on XLA CPU/TPU -- the same
    per-stream subgraphs are emitted, just scheduled as one pass), while
    the engine sees a single gradient evaluation per local step.
    ``FedDeper(fuse_grads=True)`` uses ``.twin`` when present and falls
    back to two serial calls otherwise.
    """
    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, mb)
        return l, g

    def twin(y, v, mb):
        def joint(pair):
            ly, _ = loss_fn(pair[0], mb)
            lv, _ = loss_fn(pair[1], mb)
            return ly + lv, (ly, lv)

        (_, (ly, lv)), (gy, gv) = jax.value_and_grad(
            joint, has_aux=True)((y, v))
        return ly, gy, lv, gv

    grad_fn.twin = twin
    return grad_fn


@dataclass(frozen=True)
class Strategy:
    eta: float = 0.01        # local learning rate
    server_lr: float = 1.0   # global learning rate (paper: 1)
    # beyond-paper: server-side momentum on the aggregated delta
    # (SlowMo / FedAvgM family -- the paper's Related Work cites these as
    # composable with FedDeper; 0.0 = the paper's plain aggregation)
    server_momentum: float = 0.0

    name = "base"

    # -- defaults ----------------------------------------------------------
    def client_init(self, x: Pytree) -> Pytree:
        return {}

    def server_init(self, x: Pytree) -> Pytree:
        if self.server_momentum:
            return {"mu": tmap(jnp.zeros_like, x)}
        return {}

    def broadcast(self, x: Pytree, server_state: Pytree) -> Pytree:
        return None

    def upload_template(self, x: Pytree) -> Pytree:
        """Shape/dtype template of ONE client's upload -- the uplink
        payload the comm layer compresses, carries error-feedback
        residuals for, and prices (``comm.payload_bytes``).  Every
        baseline ships one params-shaped delta; Scaffold doubles it."""
        return x

    def aggregate(self, x, server_state, uploads, p, weights=None,
                  mean_fn=None):
        """``weights`` (optional, shape (m,)): per-upload aggregation
        weights -- the async regime's staleness discounts.  ``None`` (the
        synchronous regimes) keeps the uniform mean, bit-for-bit.
        ``mean_fn`` (optional) swaps the cohort mean itself -- see the
        module docstring's one-collective contract."""
        delta = resolve_mean(mean_fn, weights)(uploads)
        if self.server_momentum:
            mu = tmap(lambda m, d:
                      (self.server_momentum * m
                       + d.astype(m.dtype)).astype(m.dtype),
                      server_state["mu"], delta)
            x = _axpy(self.server_lr, mu, x)
            return x, {"mu": mu}, {}
        x = _axpy(self.server_lr, delta, x)
        return x, server_state, {}

    # subclasses implement local_round
    def local_round(self, x, ctx, cs, batches, grad_fn):  # pragma: no cover
        raise NotImplementedError


# ---------------------------------------------------------------------------
# FedAvg  (McMahan et al. 2017)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FedAvg(Strategy):
    name = "fedavg"

    def local_round(self, x, ctx, cs, batches, grad_fn):
        def step(v, mb):
            loss, g = grad_fn(v, mb)
            return _axpy(-self.eta, g, v), loss

        v, losses = jax.lax.scan(step, x, batches)
        upload = tmap(jnp.subtract, v, x)  # v_tau - x
        return cs, upload, {"local_loss": losses.mean()}


# ---------------------------------------------------------------------------
# FedProx  (Li et al. 2020): local objective f_i(v) + (mu/2)||v - x||^2
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FedProx(Strategy):
    mu: float = 1.0  # paper fixes the proximal constant to 1
    name = "fedprox"

    def local_round(self, x, ctx, cs, batches, grad_fn):
        def step(v, mb):
            loss, g = grad_fn(v, mb)
            # v <- v - eta * (g + mu (v - x))
            v = tmap(lambda vi, gi, xi:
                     (vi - self.eta * (gi + self.mu * (vi - xi))
                      ).astype(vi.dtype), v, g, x)
            return v, loss

        v, losses = jax.lax.scan(step, x, batches)
        upload = tmap(jnp.subtract, v, x)
        return cs, upload, {"local_loss": losses.mean()}


# ---------------------------------------------------------------------------
# SCAFFOLD  (Karimireddy et al. 2020), option II control variates
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Scaffold(Strategy):
    name = "scaffold"

    def client_init(self, x):
        return {"c_i": tmap(jnp.zeros_like, x)}

    def server_init(self, x):
        return {"c": tmap(jnp.zeros_like, x)}

    def broadcast(self, x, server_state):
        return server_state["c"]

    def upload_template(self, x):
        # {dv, dc}: the paper's 2x uplink overhead, priced as such
        return {"dv": x, "dc": x}

    def local_round(self, x, ctx, cs, batches, grad_fn):
        c, c_i = ctx, cs["c_i"]

        def step(v, mb):
            loss, g = grad_fn(v, mb)
            # v <- v - eta (g - c_i + c)
            v = tmap(lambda vi, gi, cii, ci:
                     (vi - self.eta * (gi - cii + ci)).astype(vi.dtype),
                     v, g, c_i, c)
            return v, loss

        tau = jax.tree.leaves(batches)[0].shape[0]
        v, losses = jax.lax.scan(step, x, batches)
        # c_i+ = c_i - c + (x - v_tau) / (tau * eta)
        c_i_new = tmap(lambda cii, ci, xi, vi:
                       cii - ci + (xi - vi) / (tau * self.eta),
                       c_i, c, x, v)
        upload = {
            "dv": tmap(jnp.subtract, v, x),
            "dc": tmap(jnp.subtract, c_i_new, c_i),
        }
        return {"c_i": c_i_new}, upload, {"local_loss": losses.mean()}

    def aggregate(self, x, server_state, uploads, p, weights=None,
                  mean_fn=None):
        # ONE mean call over the whole {dv, dc} dict (not one per stream):
        # under the mesh placement that is the round's single psum
        d = resolve_mean(mean_fn, weights)(uploads)
        dv, dc = d["dv"], d["dc"]
        x = _axpy(self.server_lr, dv, x)
        # c += p_eff * mean(dc); doubles the uplink (the paper's 2x
        # overhead).  Uniform participation: p_eff = p = m/n, today's
        # path bit-for-bit.  Weighted (staleness-discounted) mean: the
        # weighted mean(dc) is sum_i w_i dc_i / sum_i w_i, so scaling by
        # the raw p would credit the server c with full m/n mass even
        # when every upload was discounted (or masked to zero -- the
        # mesh path's zero-weight padding lanes).  The weight-normalized
        # participation p_eff = p * sum(w)/m makes the c-update
        # sum_i w_i dc_i / n: each upload contributes exactly its
        # discounted share, padding lanes contribute nothing.  The
        # all-zero-weight guard mirrors tree_weighted_mean's: fall back
        # to the uniform p rather than zeroing the update the uniform
        # mean just computed.  Screened lanes (faults layer) arrive as a
        # LocalWeights whose global sum the mean_fn above just psum-ed:
        # p_eff then scales by the SURVIVING mass, so a screened-out
        # upload credits the server c with nothing -- same formula, one
        # weight_mass accessor for both flavors.
        if weights is None:
            p_eff = p
        else:
            s, m = weight_mass(weights)
            p_eff = p * jnp.where(s > 0, s, float(m)) / m
        c = _axpy(p_eff, dc, server_state["c"])
        return x, {"c": c}, {}


# ---------------------------------------------------------------------------
# FedDeper  (this paper, Algorithm 1)
# ---------------------------------------------------------------------------

class _Pair:
    """Unregistered (hence pytree-LEAF) y/v result pair: lets the fused
    update emit both streams from one tree traversal without colliding
    with tuples/dicts that are genuine containers in params trees."""
    __slots__ = ("y", "v")

    def __init__(self, y, v):
        self.y, self.v = y, v

@dataclass(frozen=True)
class FedDeper(Strategy):
    rho: float = 0.03   # depersonalization penalty (rho <= eta * beta)
    lam: float = 0.5    # mixing rate, lambda in [1/2, 1]
    use_pallas: bool = False  # fused deper_update kernel (TPU target)
    # Fused round engine: evaluate both per-step gradients in one joint
    # pass (``twin_grad_fn``'s ``.twin`` hook when the caller provides
    # it), update y and v in one fused op, and -- with use_pallas -- run
    # ONE whole-tree kernel launch per step with the mixing/upload tail
    # emitted by the final launch.  False is the bitwise-reference escape
    # hatch: two serial grad_fn calls, per-leaf updates, separate tail.
    fuse_grads: bool = True
    # beyond-paper: low-precision delta uploads (e.g. 'float8_e4m3fn')
    # halve the cross-client all-reduce bytes; deltas are small relative
    # to weights so fp8 range suffices (validated in tests)
    upload_dtype: str = ""
    name = "feddeper"

    def client_init(self, x):
        return {"v": tmap(jnp.asarray, x)}  # v_0 = x at round 0

    def upload_template(self, x):
        if self.upload_dtype:
            dt = jnp.dtype(self.upload_dtype)
            return tmap(lambda t: jax.ShapeDtypeStruct(t.shape, dt), x)
        return x

    def _grads(self, grad_fn):
        """(y, v, mb) -> (loss_y, gy, loss_v, gv); one joint pass when
        fused and the caller's grad_fn carries a ``.twin`` hook."""
        twin = getattr(grad_fn, "twin", None) if self.fuse_grads else None
        if twin is not None:
            return twin

        def serial(y, v, mb):
            loss_y, gy = grad_fn(y, mb)
            loss_v, gv = grad_fn(v, mb)
            return loss_y, gy, loss_v, gv
        return serial

    def _finish(self, y, v, x):
        """Mixing (Alg. 1 line 10) + upload (line 11)."""
        v_next = tmap(lambda vi, yi:
                      ((1.0 - self.lam) * vi
                       + self.lam * yi).astype(vi.dtype), v, y)
        upload = tmap(jnp.subtract, y, x)
        return v_next, upload

    def local_round(self, x, ctx, cs, batches, grad_fn):
        """Alternating SGD (Alg. 1 lines 6-9):

          y_{j+1} = y_j - eta g_i(y_j) - rho (v_j + y_j - 2x)
          v_{j+1} = v_j - eta g_i(v_j)

        then mixing (line 10):  v_0^{k+1} = (1-lam) v_tau + lam y_tau,
        upload (line 11):       y_tau - x.
        """
        eta, rho = self.eta, self.rho
        grads = self._grads(grad_fn)
        if self.use_pallas:
            from repro.kernels.ops import deper_update, deper_update_per_leaf
            kernel = deper_update if self.fuse_grads else deper_update_per_leaf

        def step(carry, mb):
            y, v = carry
            loss_y, gy, loss_v, gv = grads(y, v, mb)
            if self.use_pallas:
                y, v = kernel(y, v, x, gy, gv, eta=eta, rho=rho)
            elif self.fuse_grads:
                # one fused elementwise op per leaf-pair (y' and v'
                # computed together; same expressions as the reference)
                yv = tmap(lambda yi, vi, xi, gyi, gvi: _Pair(
                    (yi - eta * gyi
                     - rho * (vi + yi - 2.0 * xi)).astype(yi.dtype),
                    (vi - eta * gvi.astype(vi.dtype)).astype(vi.dtype)),
                    y, v, x, gy, gv)
                y = tmap(lambda p: p.y, yv)
                v = tmap(lambda p: p.v, yv)
            else:
                y = tmap(lambda yi, vi, xi, gi:
                         (yi - eta * gi
                          - rho * (vi + yi - 2.0 * xi)).astype(yi.dtype),
                         y, v, x, gy)
                v = _axpy(-eta, gv, v)
            return (y, v), (loss_y, loss_v)

        y0 = tmap(jnp.asarray, x)
        if self.use_pallas and self.fuse_grads:
            # fused tail: the LAST launch also emits mixing + upload while
            # the operands are on-chip (tau-1 scanned steps + one final)
            head = tmap(lambda t: t[:-1], batches)
            last = tmap(lambda t: t[-1], batches)
            (y, v), (ly, lv) = jax.lax.scan(step, (y0, cs["v"]), head)
            ly_f, gy, lv_f, gv = grads(y, v, last)
            y, v, v_next, upload = deper_update(
                y, v, x, gy, gv, eta=eta, rho=rho, lam=self.lam)
            ly = jnp.concatenate([ly, ly_f[None]])
            lv = jnp.concatenate([lv, lv_f[None]])
        else:
            (y, v), (ly, lv) = jax.lax.scan(step, (y0, cs["v"]), batches)
            v_next, upload = self._finish(y, v, x)
        if self.upload_dtype:
            dt = jnp.dtype(self.upload_dtype)
            upload = tmap(lambda t: t.astype(dt), upload)
        return ({"v": v_next}, upload,
                {"local_loss": ly.mean(), "personal_loss": lv.mean()})


def feddeper_star(base: FedDeper) -> FedDeper:
    """FedDeper*: same strategy, run with tau/2 local steps to align compute
    cost with single-model baselines (the caller halves the batch stack)."""
    return base


STRATEGIES = {
    "fedavg": FedAvg,
    "fedprox": FedProx,
    "scaffold": Scaffold,
    "feddeper": FedDeper,
}
