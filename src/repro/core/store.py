"""Virtual client store: O(cohort) device memory for million-client runs.

Every regime used to materialize the per-client stores (``clients`` /
``pms`` / ``ef``) as dense ``n_clients x params`` device buffers, so
memory was O(n) even though a round only touches the m-client cohort.
This module makes the store layout pluggable (DESIGN.md §11):

  * ``DenseLayout``   -- the historical layout: dense device buffers,
    in-graph gather/scatter, bit-for-bit the old trace.
  * ``VirtualLayout`` -- only the sampled cohort's rows live on device.
    Rows are gathered from / scattered back to a ``VirtualStore``
    backing tier on the host:

      - ``host``  : pinned numpy arrays, streamed with ``jax.device_put``
                    at gather time.  O(n) host RAM, O(m) device.
      - ``recon`` : stores NOTHING until a row is first touched.  Valid
                    because every store is broadcast-initialized from a
                    single template (FedAvg has no rows; FedDeper's
                    v-row and the pms row start at x0; Scaffold's
                    control variate starts at zero; EF residuals start
                    at zero), so an untouched row is *reconstructible*
                    from the template.  O(touched) host RAM.
      - ``shard`` : checkpoint-shard ``.npz`` files of ``shard_rows``
                    rows each, for populations that do not fit host
                    RAM.  Untouched shards are synthesized from the
                    template; writes are atomic (tmp + ``os.replace``,
                    the PR 7 contract).

The virtual executor (``make_virtual_round_fn``) keeps the device-side
contract of the dense engine intact: the jitted block's carry holds only
the working set (the union of the block's cohorts, fixed capacity
``block_size x m``), the round body is the SAME gather -> local rounds ->
scatter -> aggregate body with local indices, the mesh placement still
emits exactly ONE cross-client psum per round, donation still updates
the carry in place, and the host syncs once per block (gather before
the call, scatter-back after) -- PR 4's one-host-sync-per-block holds.

Bitwise contract: cohort sampling and batch draws replay the exact
in-graph rng stream (``split_round_rng`` / ``sample_cohort`` /
``jax.random.randint``) eagerly on the host, so the dense and virtual
trajectories are bit-for-bit equal on both placements (tested).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core.strategies import tmap
from repro.faults.inject import (attack_round_key, fault_round_keys,
                                 needs_attack_key)

Pytree = Any

_TIERS = ("host", "recon", "shard")


def is_virtual_store(obj) -> bool:
    """Duck-typed check used at every seam (placement, rollback guard,
    checkpoint, async delivery) so none of them has to import this
    module at module scope."""
    return hasattr(obj, "gather_rows") and hasattr(obj, "scatter_rows")


def _leaf_np(t) -> np.ndarray:
    a = np.asarray(t)
    # bf16 has no numpy dtype; the engine's stores are f32 throughout,
    # so this only defends against exotic templates
    return a


class VirtualStore:
    """One per-client store (``clients`` | ``pms`` | ``ef``) backed by a
    host tier instead of a dense device buffer.

    A VirtualStore is a pytree LEAF: jax never traces through it.  The
    engine talks to it through exactly two methods --
    ``gather_rows(idx) -> device pytree (len(idx), ...)`` and
    ``scatter_rows(idx, rows)`` (host-side, in place) -- plus
    ``clone`` (RollbackGuard snapshots), ``nbytes`` (the bench's
    ``store_bytes``), and ``save_rows``/``load_rows`` (sharded
    checkpoints, never densified)."""

    def __init__(self, template: Pytree, n: int, *, tier: str = "host",
                 shard_rows: int = 1024, shard_dir: Optional[str] = None):
        if tier not in _TIERS:
            raise ValueError(f"unknown store tier {tier!r} (want "
                             f"{'|'.join(_TIERS)})")
        leaves, treedef = jax.tree.flatten(template)
        if not leaves:
            raise ValueError("VirtualStore needs a non-empty template; "
                             "stateless stores stay {}")
        self.n = int(n)
        self.tier = tier
        self.shard_rows = int(shard_rows)
        self._treedef = treedef
        self._template = [_leaf_np(t).copy() for t in leaves]
        self._shapes = [t.shape for t in self._template]
        self._dtypes = [t.dtype for t in self._template]
        self._data: List[np.ndarray] = []
        self._rows: Dict[int, List[np.ndarray]] = {}
        self._dir: Optional[str] = None
        self._owns_dir = False
        if tier == "host":
            self._data = [
                np.broadcast_to(t, (self.n,) + t.shape).copy()
                for t in self._template
            ]
        elif tier == "shard":
            if shard_dir is None:
                shard_dir = tempfile.mkdtemp(prefix="vstore_")
                self._owns_dir = True
            os.makedirs(shard_dir, exist_ok=True)
            self._dir = shard_dir

    # -- row access ------------------------------------------------------

    def _rows_host(self, idx: np.ndarray) -> List[np.ndarray]:
        if self.tier == "host":
            return [d[idx] for d in self._data]
        if self.tier == "recon":
            out = [np.empty((len(idx),) + s, d)
                   for s, d in zip(self._shapes, self._dtypes)]
            for j, c in enumerate(idx.tolist()):
                row = self._rows.get(c, self._template)
                for o, r in zip(out, row):
                    o[j] = r
            return out
        # shard tier: group by shard file, synthesize untouched shards
        out = [np.empty((len(idx),) + s, d)
               for s, d in zip(self._shapes, self._dtypes)]
        by_shard: Dict[int, List[int]] = {}
        for j, c in enumerate(idx.tolist()):
            by_shard.setdefault(c // self.shard_rows, []).append(j)
        for s, js in by_shard.items():
            shard = self._read_shard(s)
            for j in js:
                r = int(idx[j]) - s * self.shard_rows
                for o, arr in zip(out, shard):
                    o[j] = arr[r]
        return out

    def gather_rows(self, idx) -> Pytree:
        """Device pytree of rows ``idx``: (len(idx), ...) per leaf,
        streamed host->device with ``jnp.asarray`` (``device_put``)."""
        idx = np.asarray(idx).astype(np.int64).ravel()
        rows = self._rows_host(idx)
        return jax.tree.unflatten(self._treedef,
                                  [jnp.asarray(r) for r in rows])

    def scatter_rows(self, idx, rows: Pytree) -> None:
        """Write rows ``idx`` back to the backing tier (host side, in
        place).  ``rows`` leaves are (len(idx), ...) device or host
        arrays; duplicate ids take the last write, matching
        ``.at[idx].set`` semantics."""
        idx = np.asarray(idx).astype(np.int64).ravel()
        leaves = [np.asarray(r) for r in jax.tree.leaves(rows)]
        if self.tier == "host":
            for d, r in zip(self._data, leaves):
                d[idx] = r
            return
        if self.tier == "recon":
            for j, c in enumerate(idx.tolist()):
                self._rows[c] = [np.array(r[j], copy=True) for r in leaves]
            return
        by_shard: Dict[int, List[int]] = {}
        for j, c in enumerate(idx.tolist()):
            by_shard.setdefault(c // self.shard_rows, []).append(j)
        for s, js in by_shard.items():
            shard = self._read_shard(s)
            for j in js:
                r = int(idx[j]) - s * self.shard_rows
                for arr, nw in zip(shard, leaves):
                    arr[r] = nw[j]
            self._write_shard(self._dir, s, shard)

    # -- shard-tier files ------------------------------------------------

    def _shard_len(self, s: int) -> int:
        return min(self.shard_rows, self.n - s * self.shard_rows)

    def _shard_path(self, directory: str, s: int) -> str:
        return os.path.join(directory, f"shard_{s:08d}.npz")

    def _read_shard(self, s: int) -> List[np.ndarray]:
        path = self._shard_path(self._dir, s)
        if os.path.exists(path):
            with np.load(path) as z:
                return [z[f"l{i}"].copy()
                        for i in range(len(self._template))]
        k = self._shard_len(s)
        return [np.broadcast_to(t, (k,) + t.shape).copy()
                for t in self._template]

    @staticmethod
    def _write_shard(directory: str, s: int,
                     arrays: List[np.ndarray]) -> None:
        """Atomic per-shard write: tmp + fsync + ``os.replace`` (the
        PR 7 checkpoint contract) so a crash mid-write never leaves a
        torn shard."""
        path = os.path.join(directory, f"shard_{s:08d}.npz")
        tmp = path + ".tmp.npz"
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **{f"l{i}": a for i, a in enumerate(arrays)})
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # -- lifecycle -------------------------------------------------------

    def clone(self) -> "VirtualStore":
        """Deep copy for RollbackGuard snapshots: restoring a clone must
        not alias the snapshot's buffers (or shard files)."""
        c = VirtualStore(jax.tree.unflatten(self._treedef, self._template),
                         self.n, tier=self.tier, shard_rows=self.shard_rows)
        if self.tier == "host":
            c._data = [d.copy() for d in self._data]
        elif self.tier == "recon":
            c._rows = {k: [r.copy() for r in row]
                       for k, row in self._rows.items()}
        else:
            for name in os.listdir(self._dir):
                if name.startswith("shard_") and name.endswith(".npz"):
                    shutil.copy2(os.path.join(self._dir, name),
                                 os.path.join(c._dir, name))
        return c

    def nbytes(self) -> int:
        """Backing-tier bytes actually held for rows (template excluded):
        O(n) for host, O(touched) for recon, on-disk bytes for shard."""
        if self.tier == "host":
            return int(sum(d.nbytes for d in self._data))
        if self.tier == "recon":
            row = sum(t.nbytes for t in self._template)
            return int(len(self._rows) * row)
        total = 0
        for name in os.listdir(self._dir):
            if name.startswith("shard_") and name.endswith(".npz"):
                total += os.path.getsize(os.path.join(self._dir, name))
        return int(total)

    def meta_dict(self) -> Dict[str, Any]:
        return {
            "tier": self.tier,
            "n": self.n,
            "shard_rows": self.shard_rows,
            "shapes": [list(s) for s in self._shapes],
            "dtypes": [str(d) for d in self._dtypes],
        }

    # -- sharded checkpointing (never densifies) -------------------------

    def save_rows(self, directory: str) -> None:
        """Write the backing tier under ``directory`` as atomic shard
        files + ``meta.json`` (meta last: its presence marks a complete
        store dir).  host/shard tiers write every shard; recon writes
        only the touched rows (ids + rows per shard-sized chunk)."""
        os.makedirs(directory, exist_ok=True)
        tmpl_path = os.path.join(directory, "template.npz")
        tmp = tmpl_path + ".tmp.npz"
        with open(tmp, "wb") as f:
            np.savez(f, **{f"l{i}": t
                           for i, t in enumerate(self._template)})
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, tmpl_path)
        if self.tier == "host":
            for s in range((self.n + self.shard_rows - 1)
                           // self.shard_rows):
                lo = s * self.shard_rows
                hi = lo + self._shard_len(s)
                self._write_shard(directory, s,
                                  [d[lo:hi] for d in self._data])
        elif self.tier == "recon":
            ids = np.asarray(sorted(self._rows), np.int64)
            for s in range(0, max(len(ids), 1), self.shard_rows):
                chunk = ids[s:s + self.shard_rows]
                if not len(chunk):
                    continue
                arrays = [np.stack([self._rows[int(c)][i] for c in chunk])
                          for i in range(len(self._template))]
                path = os.path.join(directory,
                                    f"touched_{s // self.shard_rows:08d}"
                                    ".npz")
                tmp = path + ".tmp.npz"
                with open(tmp, "wb") as f:
                    np.savez(f, ids=chunk,
                             **{f"l{i}": a for i, a in enumerate(arrays)})
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
        else:
            for name in sorted(os.listdir(self._dir)):
                if name.startswith("shard_") and name.endswith(".npz"):
                    tmp = os.path.join(directory, name + ".tmp")
                    shutil.copy2(os.path.join(self._dir, name), tmp)
                    os.replace(tmp, os.path.join(directory, name))
        meta_path = os.path.join(directory, "meta.json")
        tmp = meta_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.meta_dict(), f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, meta_path)

    def load_rows(self, directory: str) -> None:
        """Load a ``save_rows`` directory back into this store.  The
        saved layout must match this store's (tier, n, leaf shapes) --
        resuming a virtual run under a different ``--store`` spec fails
        fast here instead of silently retraining."""
        meta_path = os.path.join(directory, "meta.json")
        if not os.path.exists(meta_path):
            raise ValueError(
                f"virtual-store checkpoint dir {directory!r} is missing "
                "meta.json (incomplete or not a store checkpoint)")
        with open(meta_path) as f:
            meta = json.load(f)
        want = self.meta_dict()
        for k in ("tier", "n", "shapes", "dtypes"):
            if meta.get(k) != want[k]:
                raise ValueError(
                    f"virtual-store layout mismatch on {k!r}: checkpoint "
                    f"has {meta.get(k)!r}, this run expects {want[k]!r} "
                    "(pass the --store spec the checkpoint was written "
                    "with)")
        if self.tier == "recon":
            self._rows = {}
            for name in sorted(os.listdir(directory)):
                if not (name.startswith("touched_")
                        and name.endswith(".npz")):
                    continue
                with np.load(os.path.join(directory, name)) as z:
                    ids = z["ids"]
                    arrays = [z[f"l{i}"]
                              for i in range(len(self._template))]
                    for j, c in enumerate(ids.tolist()):
                        self._rows[int(c)] = [np.array(a[j], copy=True)
                                              for a in arrays]
            return
        shard_names = [name for name in sorted(os.listdir(directory))
                       if name.startswith("shard_")
                       and name.endswith(".npz")]
        if self.tier == "host":
            for name in shard_names:
                s = int(name[len("shard_"):-len(".npz")])
                lo = s * self.shard_rows
                with np.load(os.path.join(directory, name)) as z:
                    for i, d in enumerate(self._data):
                        arr = z[f"l{i}"]
                        d[lo:lo + arr.shape[0]] = arr
            return
        for name in os.listdir(self._dir):
            if name.startswith("shard_") and name.endswith(".npz"):
                os.unlink(os.path.join(self._dir, name))
        for name in shard_names:
            shutil.copy2(os.path.join(directory, name),
                         os.path.join(self._dir, name))

    def __repr__(self) -> str:
        return (f"VirtualStore(tier={self.tier!r}, n={self.n}, "
                f"leaves={len(self._template)})")


# ---------------------------------------------------------------------------
# layouts
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StoreLayout:
    """Dense layout (the default): per-client stores are dense device
    buffers and every gather/scatter stays in-graph -- bit-for-bit the
    historical trace."""
    name = "dense"
    virtual = False

    @property
    def spec(self) -> str:
        return "dense"

    def init_store(self, template: Pytree, n: int) -> Pytree:
        return eng.broadcast_client_store(template, n)


DenseLayout = StoreLayout


@dataclass(frozen=True)
class VirtualLayout(StoreLayout):
    """Virtual layout: stores are ``VirtualStore`` backing tiers; only
    the cohort working set lives on device (``make_virtual_round_fn``)."""
    tier: str = "host"
    shard_rows: int = 1024
    shard_dir: Optional[str] = None
    name = "virtual"
    virtual = True

    @property
    def spec(self) -> str:
        return f"virtual:{self.tier}"

    def init_store(self, template: Pytree, n: int) -> Pytree:
        if not jax.tree.leaves(template):
            return {}
        return VirtualStore(template, n, tier=self.tier,
                            shard_rows=self.shard_rows,
                            shard_dir=self.shard_dir)


def make_layout(spec=None) -> StoreLayout:
    """Parse a ``--store`` spec:

      ``None`` | ``"dense"``      -> DenseLayout
      ``"virtual"``               -> VirtualLayout(host)
      ``"virtual:host"``          -> VirtualLayout(host)
      ``"virtual:recon"``         -> VirtualLayout(recon)
      ``"virtual:shard[:<dir>]"`` -> VirtualLayout(shard), optional dir

    An already-constructed StoreLayout passes through.  Lexing/errors
    via the shared ``configs.specs.parse_spec`` mini-language helper;
    the tier directory (``shard`` only) may itself contain colons."""
    if spec is None or isinstance(spec, StoreLayout):
        return spec or StoreLayout()
    from repro.configs.specs import SpecError, parse_spec
    p = parse_spec(spec, flag="--store", heads=("dense", "virtual"),
                   arity={"virtual": (0, 2)}, greedy=("virtual",),
                   head_label="layout",
                   head_hint="(grammar: dense | "
                             "virtual[:host|:recon|:shard[:dir]])")
    if p.head == "dense":
        return StoreLayout()
    if not p.args:
        return VirtualLayout()
    tier = p.args[0].strip()
    if tier not in _TIERS:
        raise SpecError(f"unknown store spec {spec!r} (tier must be "
                        f"{'|'.join(_TIERS)})")
    if tier == "shard" and len(p.args) > 1:
        return VirtualLayout(tier="shard", shard_dir=p.args[1])
    return VirtualLayout(tier=tier)


def resolve_layout(layout) -> StoreLayout:
    return make_layout(layout)


def state_store_bytes(state: Dict[str, Any]) -> Optional[int]:
    """Sum of backing-tier bytes over the state's virtual stores; None
    when the state holds no virtual store (dense layout)."""
    sizes = [v.nbytes() for v in state.values() if is_virtual_store(v)]
    if not sizes:
        return None
    return int(sum(sizes))


# ---------------------------------------------------------------------------
# the virtual executor
# ---------------------------------------------------------------------------

def make_virtual_round_fn(sim, strategy, grad_fn, data, *, layout,
                          placement=None, donate: bool = True,
                          compressor=None, faults=None,
                          block_size: Optional[int] = None,
                          robust=None):
    """Round/block executor over virtual stores: ``fn(state) -> (state,
    metrics)`` with the same contract as ``make_cohort_round``
    (``block_size=None``) or ``make_block_fn`` (metrics stacked
    ``(block_size,)``).

    Per call the host (1) replays the next ``block_size`` rounds' rng
    splits to learn their cohorts WITHOUT consuming ``state['rng']``
    (the ``peek_sampled_clients`` idiom), (2) builds the block's working
    set -- the first-occurrence union of the cohorts, padded to fixed
    capacity ``block_size x m`` so the jit compiles once per block size
    (pad rows repeat a real id, are never addressed by a local index,
    and are dropped at scatter-back), (3) draws every round's minibatch
    indices with the SAME ``jax.random.randint`` the dense body traces
    (bitwise-identical values) and materializes cohort data rows --
    ``data`` may be dense arrays or an on-demand source exposing
    ``take(idx) / n_rows``, so no ``n``-leading array need ever exist,
    (4) gathers working-set rows into the device carry and runs ONE
    AOT-compiled jitted block (donated; in-graph body identical to the
    dense round body with local indices; one psum per round under the
    mesh placement), then (5) scatters the real working-set rows back
    to the backing tier.  Host sync: once per block, after the call.

    The returned fn exposes ``peak_bytes`` (compiled temp+output bytes,
    set at first call) and ``trace(state)`` (the block's jaxpr, for
    collective counting)."""
    from repro.robust.reducers import make_robust
    placement = placement or eng.VmapPlacement()
    placement.check(sim)
    if faults is not None and not faults.active:
        faults = None
    robust = make_robust(robust)
    if robust is not None:
        robust.check_cohort(sim.m_sampled)
    n, m, tau, b = (sim.n_clients, sim.m_sampled, sim.tau, sim.batch_size)
    stateful = compressor is not None and compressor.stateful
    K = 1 if block_size is None else int(block_size)
    if K < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    scalar_metrics = block_size is None
    w_cap = K * m

    if hasattr(data, "take"):
        take, n_i = data.take, int(data.n_rows)
    else:
        data_host = tmap(np.asarray, data)
        n_i = jax.tree.leaves(data_host)[0].shape[1]

        def take(idx):
            return tmap(lambda t: t[idx], data_host)

    def body(carry, ops):
        # identical to engine.make_round_body's body with the in-graph
        # cohort sample replaced by host-fed local indices; k_sel is
        # split (stream layout preserved) but unused -> DCE'd
        lidx, batches = ops
        rng, _k_sel, k_batch = eng.split_round_rng(carry["rng"])
        cs = eng.gather_client_state(carry["clients"], lidx)
        ctx = strategy.broadcast(carry["x"], carry["server"])
        comm_kw = {}
        if compressor is not None:
            comm_kw = dict(
                compressor=compressor,
                ef=eng.gather_client_state(carry.get("ef", {}), lidx),
                keys=eng.comm_round_keys(k_batch, m))
        if faults is not None:
            comm_kw.update(
                faults=faults,
                pms=eng.gather_client_state(carry["pms"], lidx),
                fkeys=fault_round_keys(k_batch, m))
            if needs_attack_key(faults):
                comm_kw["akey"] = attack_round_key(k_batch)
        if robust is not None:
            comm_kw["robust"] = robust
        new_cs, pms_new, x, server, metrics, ef_new = placement.execute(
            strategy, carry["x"], carry["server"], ctx, cs, batches,
            grad_fn, sim.p, **comm_kw)
        if faults is not None:
            metrics = dict(metrics)
            for k in ("screened", "dropped"):
                metrics[k] = metrics[k] * m
        out = {
            "x": x,
            "clients": placement.constrain_store(
                eng.scatter_cohort_rows(carry["clients"], lidx, new_cs)),
            "pms": placement.constrain_store(
                eng.scatter_cohort_rows(carry["pms"], lidx, pms_new)),
            "server": server,
            "rng": rng,
            "round": carry["round"] + 1,
        }
        if stateful:
            out["ef"] = placement.constrain_store(
                eng.scatter_cohort_rows(carry["ef"], lidx, ef_new))
        return out, metrics

    def blocked(carry, lidx, batches):
        if scalar_metrics:
            return body(carry, (lidx[0], tmap(lambda t: t[0], batches)))
        return jax.lax.scan(body, carry, (lidx, batches))

    jitted = (jax.jit(blocked, donate_argnums=(0,)) if donate
              else jax.jit(blocked))
    cache: Dict[str, Any] = {}

    def _operands(state):
        # (1) peek the block's cohorts by replaying the rng stream
        r = state["rng"]
        idxs, kbs = [], []
        for _ in range(K):
            r, k_sel, k_batch = eng.split_round_rng(r)
            idxs.append(np.asarray(eng.sample_cohort(k_sel, n, m)))
            kbs.append(k_batch)
        # (2) working set: first-occurrence union, fixed capacity K*m
        pos: Dict[int, int] = {}
        order: List[int] = []
        for idx in idxs:
            for c in idx.tolist():
                if c not in pos:
                    pos[c] = len(order)
                    order.append(c)
        w_real = len(order)
        wids = np.asarray(order + [order[0]] * (w_cap - w_real), np.int64)
        lidx = np.asarray([[pos[c] for c in idx.tolist()] for idx in idxs],
                          np.int32)
        # (3) batches, drawn with the dense body's exact randint stream
        lanes = np.arange(m)[:, None, None]
        per_round = []
        for idx, k_batch in zip(idxs, kbs):
            bidx = np.asarray(
                jax.random.randint(k_batch, (m, tau, b), 0, n_i))
            rows = take(idx)
            per_round.append(tmap(lambda t: t[lanes, bidx], rows))
        batches = tmap(lambda *ts: jnp.asarray(np.stack(ts)), *per_round)
        return wids, w_real, jnp.asarray(lidx), batches

    def _build_carry(state, wids):
        carry = {"x": state["x"], "server": state["server"],
                 "rng": state["rng"], "round": state["round"]}
        stores = {}
        for key in ("clients", "pms", "ef"):
            s = state.get(key)
            if s is None:
                continue
            if is_virtual_store(s):
                stores[key] = s
                carry[key] = s.gather_rows(wids)
            else:
                carry[key] = s  # {} for stateless strategies
        if stores and placement.name == "mesh":
            placed = placement.place_state(
                {k: carry[k] for k in stores})
            carry.update(placed)
        return carry, stores

    def round_fn(state):
        if stateful and "ef" not in state:
            raise ValueError(
                f"compressor {compressor.name!r} carries error-feedback "
                "residuals: init the state with the same compressor "
                "(init_cohort_state/init_sim_state(..., compressor=...))")
        wids, w_real, lidx, batches = _operands(state)
        carry, stores = _build_carry(state, wids)
        fn = cache.get("fn")
        if fn is None:
            compiled = jitted.lower(carry, lidx, batches).compile()
            try:
                ma = compiled.memory_analysis()
                round_fn.peak_bytes = (int(ma.temp_size_in_bytes)
                                       + int(ma.output_size_in_bytes))
            except Exception:
                round_fn.peak_bytes = None
            cache["fn"] = fn = compiled
        out, metrics = fn(carry, lidx, batches)
        # one host sync per block: pull the real working-set rows and
        # push them to the backing tier (pad rows dropped)
        for key, store in stores.items():
            store.scatter_rows(
                wids[:w_real],
                tmap(lambda t: np.asarray(t)[:w_real], out[key]))
        new_state = dict(state)
        for key in ("x", "server", "rng", "round"):
            new_state[key] = out[key]
        return new_state, metrics

    def trace(state):
        """The block's jaxpr (for collective counting in tests)."""
        wids, _w_real, lidx, batches = _operands(state)
        carry, _stores = _build_carry(state, wids)
        return jax.make_jaxpr(blocked)(carry, lidx, batches)

    round_fn.peak_bytes = None
    round_fn.layout = layout
    round_fn.block_size = K
    round_fn.trace = trace
    return round_fn
