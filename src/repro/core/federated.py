"""Datacenter regime: FedDeper rounds as sharded multi-pod train steps.

The FL->datacenter mapping (DESIGN.md §3): a *client* is a slice of the
mesh (the 'data' axis single-pod, the 'pod' axis multi-pod).  One
``round_step`` = tau local steps (lax.scan over microbatches, zero
cross-client traffic) + one delta-mean whose lowering is the cross-client
all-reduce.  Synchronous data-parallel SGD (= FedAvg tau=1) is the
comparator: FedDeper divides cross-client collective bytes per optimizer
step by tau at the price of 2x local gradient compute.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.strategies import Strategy, tmap
from repro.models import transformer

Pytree = Any


def make_lm_grad_fn(cfg, *, chunkwise=True, use_pallas=False,
                    remat: bool = False, unroll=1):
    """``remat`` checkpoints each layer-scan body (classic scan remat:
    only the residual carry is saved between layers; layer internals are
    recomputed during the backward pass)."""
    def loss(params, mb):
        l, _ = transformer.loss_fn(cfg, params, mb, chunkwise=chunkwise,
                                   use_pallas=use_pallas, unroll=unroll,
                                   remat=remat)
        return l

    def grad_fn(params, mb):
        l, g = jax.value_and_grad(loss)(params, mb)
        return l, g

    return grad_fn


def make_round_step(cfg, strategy: Strategy, *, chunkwise=True,
                    use_pallas=False, remat=False, unroll=1):
    """Returns ``round_step(x, server_state, client_state, batch)``.

    batch: pytree with leading (C, tau, b, ...) dims -- C clients, tau
    microbatches each.  x is client-replicated; client_state carries a
    leading C dim.  One call = one FL round = one cross-client sync.
    """
    grad_fn = make_lm_grad_fn(cfg, chunkwise=chunkwise,
                              use_pallas=use_pallas, remat=remat,
                              unroll=unroll)

    def round_step(x, server_state, client_state, batch):
        ctx = strategy.broadcast(x, server_state)

        def per_client(cs, cb):
            return strategy.local_round(x, ctx, cs, cb, grad_fn)

        new_cs, uploads, metrics = jax.vmap(per_client)(client_state, batch)
        x, server_state, _ = strategy.aggregate(x, server_state, uploads,
                                                p=1.0)
        metrics = {k: v.mean() for k, v in metrics.items()}
        return x, server_state, new_cs, metrics

    return round_step


def make_sync_train_step(cfg, *, eta: float = 1e-3, chunkwise=True,
                         use_pallas=False, remat=False, unroll=1):
    """Synchronous data-parallel SGD baseline (= FedAvg with tau = 1):
    gradient all-reduce every step.  batch: (B, S) global."""
    grad_fn = make_lm_grad_fn(cfg, chunkwise=chunkwise,
                              use_pallas=use_pallas, remat=remat,
                              unroll=unroll)

    def train_step(x, batch):
        loss, g = grad_fn(x, batch)
        x = tmap(lambda xi, gi: (xi - eta * gi).astype(xi.dtype), x, g)
        return x, {"loss": loss}

    return train_step


# ---------------------------------------------------------------------------
# serving steps (inference shapes)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, *, chunkwise=True, unroll=1):
    def prefill_step(params, batch, cache):
        return transformer.prefill(cfg, params, batch, cache,
                                   chunkwise=chunkwise, unroll=unroll)

    return prefill_step


def make_decode_step(cfg, *, chunkwise=True, unroll=1, seq_shard=None):
    def serve_step(params, cache, tokens, pos):
        logits, cache = transformer.decode_step(cfg, params, cache, tokens,
                                                pos, chunkwise=chunkwise,
                                                unroll=unroll,
                                                seq_shard=seq_shard)
        next_tok = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
        return next_tok, logits, cache

    return serve_step
