"""FedDeper core: strategies + simulation and datacenter round machinery."""
from repro.core.strategies import (  # noqa: F401
    FedAvg,
    FedDeper,
    FedProx,
    LocalWeights,
    Scaffold,
    STRATEGIES,
    Strategy,
    tree_weighted_mean,
    twin_grad_fn,
    weight_mass,
)
from repro.core.async_rounds import (  # noqa: F401
    AsyncSimConfig,
    init_async_state,
    make_async_round_fn,
    staleness_weights,
)
from repro.core.engine import (  # noqa: F401
    MeshPlacement,
    VmapPlacement,
    make_cohort_round,
    make_dispatch_cohort,
    make_placement,
    make_round_body,
    pad_cohort,
)
from repro.core.rounds import (  # noqa: F401
    RollbackGuard,
    SimConfig,
    broadcast_client_store,
    gather_client_state,
    init_sim_state,
    make_block_fn,
    make_global_eval,
    make_personal_eval,
    make_round_fn,
    peek_round_faults,
    peek_sampled_clients,
    run_blocks,
    run_rounds,
    scatter_client_rows,
    state_is_finite,
)
from repro.robust.reducers import (  # noqa: F401
    RobustConfig,
    make_robust,
    robust_reduce,
)
from repro.core.store import (  # noqa: F401
    DenseLayout,
    StoreLayout,
    VirtualLayout,
    VirtualStore,
    is_virtual_store,
    make_layout,
    make_virtual_round_fn,
    state_store_bytes,
)
from repro.core.federated import (  # noqa: F401
    make_decode_step,
    make_lm_grad_fn,
    make_prefill_step,
    make_round_step,
    make_sync_train_step,
)
