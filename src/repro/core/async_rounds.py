"""Buffered asynchronous aggregation regime (FedBuff-style).

The synchronous regimes (`rounds.py` simulation, `federated.py`
datacenter) block every round on the slowest sampled client -- exactly
the straggler regime the paper's motivation (slow, unstable convergence
under heterogeneity and limited bandwidth) cares about.  This module adds
the third regime: a versioned global model with a bounded upload buffer.

  * up to ``m_concurrent`` clients train simultaneously, each against the
    global-model *snapshot it pulled* (slow clients keep training on old
    versions while fast clients lap them);
  * client wall-clock is a per-client delay drawn once from a configurable
    straggler distribution (``AsyncSimConfig.client_delays``);
  * completed uploads land in a buffer together with their staleness
    ``s = version_now - version_pulled``; once ``buffer_size`` uploads have
    arrived the server applies one staleness-discounted aggregate with
    polynomial weights ``(1 + s)^-alpha`` and bumps the version.

A client's local computation depends only on its pulled snapshot and its
own batch draws, so the simulator runs the tau local steps eagerly at
dispatch time and holds the finished payload until the client's simulated
finish time -- semantically identical to training during the delay.

Degenerate case (tested bit-for-bit in ``tests/test_async_rounds.py``):
``delay=0, buffer_size=m_concurrent, alpha=0`` reproduces the synchronous
``make_round_fn`` trajectory exactly, for every strategy.

See DESIGN.md §4 for buffer semantics and the staleness-weighting math.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (VmapPlacement, broadcast_client_store,
                               comm_round_keys, draw_cohort_batches,
                               gather_client_state, init_ef_store,
                               make_dispatch_cohort, pad_cohort,
                               sample_cohort, scatter_client_rows,
                               scatter_cohort_rows, split_round_rng)
from repro.core.strategies import Strategy, tmap

Pytree = Any


@dataclass(frozen=True)
class AsyncSimConfig:
    """Async-regime knobs.  ``delay`` is the mean client delay in simulated
    time units; staleness comes from version drift, so only delay *ratios*
    between clients matter, not the unit."""
    n_clients: int
    m_concurrent: int        # clients training simultaneously (slots)
    buffer_size: int         # uploads per aggregation (FedBuff's K)
    tau: int
    batch_size: int
    alpha: float = 0.5       # staleness discount exponent; 0 = no discount
    delay: float = 0.0       # mean per-client delay; 0 = all instant
    delay_dist: str = "lognormal"  # 'constant' | 'uniform' | 'lognormal'
    delay_sigma: float = 1.0       # lognormal shape (straggler heaviness)
    seed: int = 0
    # uplink bandwidth in BYTES per simulated-time unit; 0 disables the
    # bandwidth model (bit-compatible with pre-comm configs).  When set,
    # every finished client's delivery is pushed back by
    # payload_bytes / bandwidth -- the straggler sim becomes
    # bandwidth-aware, and compressing the uplink (repro.comm) directly
    # shortens the queue
    bandwidth: float = 0.0

    def __post_init__(self):
        if not (1 <= self.m_concurrent <= self.n_clients):
            raise ValueError("need 1 <= m_concurrent <= n_clients")
        if self.buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")

    @property
    def p(self) -> float:
        """Per-aggregation participation fraction (Scaffold's c-update)."""
        return self.buffer_size / self.n_clients

    def client_delays(self) -> np.ndarray:
        """Deterministic per-client delays, drawn once per config."""
        if self.delay <= 0:
            return np.zeros(self.n_clients)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 0xA57C]))
        if self.delay_dist == "constant":
            d = np.full(self.n_clients, float(self.delay))
        elif self.delay_dist == "uniform":
            d = rng.uniform(0.0, 2.0 * self.delay, self.n_clients)
        elif self.delay_dist == "lognormal":
            # mean-normalized heavy tail: E[d] = delay for any sigma
            d = self.delay * rng.lognormal(
                -0.5 * self.delay_sigma ** 2, self.delay_sigma,
                self.n_clients)
        else:
            raise ValueError(f"unknown delay_dist {self.delay_dist!r}")
        return d


def staleness_weights(staleness, alpha: float) -> jax.Array:
    """Polynomial staleness discount (Xie et al. 2019; FedBuff):
    w_i = (1 + s_i)^-alpha.  alpha=0 recovers the uniform mean."""
    s = jnp.asarray(staleness, jnp.float32)
    return (1.0 + s) ** (-alpha)


def init_async_state(acfg: AsyncSimConfig, strategy: Strategy, x: Pytree,
                     compressor=None, placement=None, layout=None):
    """Async simulation state: the jax parts mirror ``init_sim_state``
    (same PRNG stream, same store layout via the shared helpers);
    scheduling bookkeeping lives host-side.  ``x`` is copied so the
    donating aggregate never invalidates caller-held params.  A stateful
    ``compressor`` adds the per-client error-feedback store ``ef``
    (mirroring ``init_cohort_state``).  A mesh ``placement`` lays the
    jax-side stores out per ``MeshPlacement.state_specs`` (client/pms/ef
    over the client axis, model replicated) -- the host-side scheduling
    keys (slots/buffer/delays/counters) stay host-side.  ``layout``
    (core.store) picks dense stores (default) or virtual backing tiers:
    dispatch then gathers rows host->device per cohort and delivery
    scatters them back host-side, so device memory stays O(cohort)."""
    from repro.core.store import resolve_layout
    layout = resolve_layout(layout)
    x = tmap(jnp.copy, x)
    clients = layout.init_store(strategy.client_init(x), acfg.n_clients)
    pms = layout.init_store(x, acfg.n_clients)
    state = {
        "x": x,
        "clients": clients,
        "pms": pms,
        "server": strategy.server_init(x),
        "rng": jax.random.PRNGKey(acfg.seed),
        "round": 0,              # completed aggregations
        "version": 0,            # global model version
        "t": 0.0,                # simulated wall-clock
        "slots": [None] * acfg.m_concurrent,
        "buffer": [],            # delivered uploads awaiting aggregation
        "delays": acfg.client_delays(),
    }
    ef = init_ef_store(strategy, x, acfg.n_clients, compressor, layout)
    if jax.tree.leaves(ef):
        state["ef"] = ef
    if placement is not None:
        placed = {k: state[k] for k in ("x", "clients", "pms", "server")}
        if "ef" in state:
            placed["ef"] = state["ef"]
        state.update(placement.place_state(placed))
    return state


def make_async_round_fn(acfg: AsyncSimConfig, strategy: Strategy, grad_fn,
                        data: Dict[str, jax.Array], *, donate: bool = True,
                        placement=None, compressor=None, faults=None):
    """Returns ``async_round(state) -> (state, metrics)`` advancing the
    event simulation until exactly one buffered aggregation completes --
    the same contract as ``make_round_fn``, so ``run_rounds`` drives it.

    data: per-client arrays with leading (n_clients, N_i) dims.

    ``donate=True`` (default) mirrors ``make_round_fn``: the global model
    and the client/pms stores update in place, so a state passed to
    ``async_round`` is CONSUMED -- keep using only the returned state.
    ``donate=False`` restores the copying semantics bit-for-bit.

    ``placement`` (engine.py) maps each dispatch cohort's tau-scans; the
    default vmap placement is the historical path.  A mesh placement
    distributes each dispatch over the client axis -- cohort and buffer
    sizes that do not divide the axis are padded with masked lanes
    (edge-replicated for dispatch, zero-valued zero-WEIGHT for the
    aggregation buffer; ``engine.pad_cohort``) -- and routes every
    aggregation through ``MeshPlacement.aggregate_buffer``, so the
    staleness-weighted mean lowers to ONE cross-client psum instead of
    the host-side ``agg_weighted`` jit.

    ``compressor`` (repro.comm) compresses each finished client's upload;
    with ``acfg.bandwidth > 0`` the delivery time additionally pays
    ``payload_bytes / bandwidth``, so compression directly shortens the
    simulated straggler queue (the bandwidth-aware regime).  A stateful
    compressor's residual rows are gathered at dispatch and scattered at
    delivery, exactly like the client store.

    ``faults`` (repro.faults.FaultConfig): the async regime supports the
    DEADLINE fault class only -- a dispatch whose simulated completion
    (client delay + upload delay) exceeds ``faults.deadline`` never
    delivers: its slot frees at the deadline, its payload is discarded
    (the sync drop semantics: client/pms/ef rows keep their pre-dispatch
    values), and the aggregation's ``dropped`` metric counts it.  The
    drop/corrupt/clip classes are sync-regime screening; requesting them
    here fails fast rather than silently ignoring them."""
    if faults is not None:
        if faults.active:
            raise ValueError(
                "async regime: only deadline faults are supported "
                f"(got {faults.spec!r}); drop/corrupt/clip screening is "
                "the synchronous engine's (make_round_fn(faults=...))")
        deadline = faults.deadline if faults.deadline > 0 else None
    else:
        deadline = None
    if deadline is not None:
        d0 = acfg.client_delays()
        if (d0 > deadline).all():
            raise ValueError(
                f"deadline {deadline:g} is below every client delay "
                f"(min {d0.min():g}): no upload can ever deliver")
    n, tau, b = acfg.n_clients, acfg.tau, acfg.batch_size
    placement = placement or VmapPlacement()
    mesh_placed = placement.name == "mesh"
    stateful = compressor is not None and compressor.stateful
    _donate = (lambda *a: functools.partial(jax.jit, donate_argnums=a)) \
        if donate else (lambda *a: jax.jit)
    _scatter = scatter_client_rows if donate else \
        jax.jit(scatter_cohort_rows)

    def _scatter_row(store, c, row):
        """Delivery scatter: a virtual store takes the row host-side (its
        backing tier updates in place, device memory untouched); a dense
        store goes through the donated jitted scatter as before."""
        if hasattr(store, "scatter_rows"):
            store.scatter_rows(np.asarray([int(c)]),
                               tmap(lambda t: np.asarray(t)[None], row))
            return store
        return _scatter(store, c, row)
    dispatch_cohort = make_dispatch_cohort(strategy, grad_fn, placement,
                                           compressor)

    @_donate(0, 2)
    def train_cohort(*args):
        """tau local steps for a cohort of dispatched clients: the shared
        ``engine.make_dispatch_cohort`` body (every operand carries the
        cohort axis -- each client sees its own pulled model), wrapped
        here only for donation.  Under a compressor the operands grow
        (ef rows, comm keys) and the outputs grow (new ef rows) -- see
        ``engine.make_per_client``.

        ``xs`` (the per-cohort model broadcast) and ``cs`` (the gathered
        client state) are freshly materialized per dispatch and donated:
        their buffers are reused for the cohort-shaped outputs (uploads/
        pms and new_cs), halving the transient dispatch allocation.

        Retraces once per distinct cohort size f in [1, m_concurrent]
        (in practice the first full dispatch plus the small refill sizes
        the delay pattern produces).  Padding every dispatch to
        m_concurrent with masked lanes would cap this at one compile but
        costs wasted lane compute and complicates the bit-for-bit
        degenerate-case guarantee, so the vmap simulator keeps the
        honest shapes.  (The mesh placement DOES pad -- to the next
        multiple of the client axis, inside ``cohort_map`` -- because
        there non-dividing shapes cannot run at all; that caps its
        retraces at one per padded size.)"""
        return dispatch_cohort(*args)

    # the bandwidth model's per-upload wire bytes: static in the config
    # + upload shapes, resolved lazily at the first dispatch (the upload
    # template needs the model pytree, which lives in the state)
    _wire: Dict[str, float] = {}

    def _upload_delay(state) -> float:
        if acfg.bandwidth <= 0:
            return 0.0
        if "per_upload" not in _wire:
            from repro.comm import payload_bytes
            _wire["per_upload"] = payload_bytes(
                compressor, strategy.upload_template(state["x"]))
        return _wire["per_upload"] / acfg.bandwidth

    # x and server are donated: the versioned global model updates in
    # place at every aggregation (_aggregate immediately rebinds
    # state["x"]/state["server"] to the outputs, so the consumed inputs
    # are never touched again)
    @_donate(0, 1)
    def agg_plain(x, server, uploads):
        return strategy.aggregate(x, server, uploads, acfg.p)

    @_donate(0, 1)
    def agg_weighted(x, server, uploads, w):
        return strategy.aggregate(x, server, uploads, acfg.p, weights=w)

    # mesh twins of the two aggregates: the same strategy.aggregate, but
    # lowered through the placement so the (weighted) mean is the round's
    # single cross-client psum.  p is derived from the PADDED buffer
    # length (static per trace): padding lanes carry zero weight, so
    # Scaffold's weight-normalized participation stays sum(w)/n whatever
    # the padding -- and on the unweighted path no padding ever happens
    # (it is only taken when pad == 0, see _aggregate).
    @_donate(0, 1)
    def agg_mesh_plain(x, server, uploads):
        m = jax.tree.leaves(uploads)[0].shape[0]
        return placement.aggregate_buffer(strategy, x, server, uploads,
                                          m / n)

    @_donate(0, 1)
    def agg_mesh_weighted(x, server, uploads, w):
        m = jax.tree.leaves(uploads)[0].shape[0]
        return placement.aggregate_buffer(strategy, x, server, uploads,
                                          m / n, weights=w)

    def _dispatch(state):
        """Fill free slots: sample idle clients, draw their batches, run
        their local rounds against the current model, schedule delivery."""
        free = [i for i, s in enumerate(state["slots"]) if s is None]
        if not free:
            return
        f = len(free)
        rng, k_sel, k_batch = split_round_rng(state["rng"])
        state["rng"] = rng
        busy = [s["client"] for s in state["slots"] if s is not None]
        if busy:
            p = np.ones(n)
            p[busy] = 0.0
            idx = sample_cohort(k_sel, n, f, p=jnp.asarray(p / p.sum()))
        else:
            # identical draw to make_round_fn (degenerate-case equivalence)
            idx = sample_cohort(k_sel, n, f)
        batches = draw_cohort_batches(data, k_batch, idx, tau, b)
        cs = gather_client_state(state["clients"], idx)
        ctx = strategy.broadcast(state["x"], state["server"])
        bcast = lambda t: jnp.broadcast_to(t, (f,) + t.shape)  # noqa: E731
        if compressor is not None:
            ef = gather_client_state(state.get("ef", {}), idx)
            new_cs, uploads, pms, metrics, ef_new = train_cohort(
                tmap(bcast, state["x"]), tmap(bcast, ctx), cs, batches,
                ef, comm_round_keys(k_batch, f))
        else:
            new_cs, uploads, pms, metrics = train_cohort(
                tmap(bcast, state["x"]), tmap(bcast, ctx), cs, batches)
            ef_new = {}

        up_delay = _upload_delay(state)
        idx_np = np.asarray(idx)
        for j, slot in enumerate(free):
            c = int(idx_np[j])
            wall = float(state["delays"][c]) + up_delay
            timed_out = deadline is not None and wall > deadline
            state["slots"][slot] = {
                "client": c,
                "version": state["version"],
                # a straggler past the deadline frees its slot AT the
                # deadline (the server stops waiting); its payload is
                # dead on arrival and never materialized host-side
                "finish_t": state["t"] + (deadline if timed_out else wall),
                "timed_out": timed_out,
                "payload": None if timed_out else tmap(
                    lambda t: t[j], (new_cs, uploads, pms, ef_new)),
                "metrics": {k: v[j] for k, v in metrics.items()},
            }

    def _aggregate(state):
        """Apply the staleness-weighted aggregate over the full buffer."""
        buf, state["buffer"] = state["buffer"], []
        uploads = tmap(lambda *ts: jnp.stack(ts),
                       *[item["upload"] for item in buf])
        stal = np.array([item["staleness"] for item in buf], np.float32)
        if mesh_placed:
            uploads, m_real = pad_cohort(uploads, placement.axis_size,
                                         mode="zero")
            pad = jax.tree.leaves(uploads)[0].shape[0] - m_real
            uploads = placement.place_uploads(uploads)
            if acfg.alpha == 0.0 and pad == 0:
                # uniform weights, no masking needed: the unweighted
                # psum-mean path (mean-of-local-means pmean), which on a
                # 1-device mesh is bit-identical to the vmap agg_plain
                # (the sync degenerate pin, extended to the mesh)
                x, server, agg_m = agg_mesh_plain(state["x"],
                                                  state["server"], uploads)
            else:
                w = staleness_weights(stal, acfg.alpha)
                if pad:
                    w = jnp.concatenate([w, jnp.zeros(pad, w.dtype)])
                x, server, agg_m = agg_mesh_weighted(
                    state["x"], state["server"], uploads, w)
        elif acfg.alpha == 0.0:
            # uniform weights: take the legacy path, bit-identical to sync
            x, server, agg_m = agg_plain(state["x"], state["server"],
                                         uploads)
        else:
            w = staleness_weights(stal, acfg.alpha)
            x, server, agg_m = agg_weighted(state["x"], state["server"],
                                            uploads, w)
        state["x"], state["server"] = x, server
        state["version"] += 1
        state["round"] += 1
        metrics = {}
        keys = buf[0]["metrics"].keys()
        for k in keys:
            metrics[k] = jnp.stack([item["metrics"][k]
                                    for item in buf]).mean()
        metrics.update(agg_m)
        metrics.update({
            "staleness_mean": float(stal.mean()),
            "staleness_max": float(stal.max()),
            "sim_time": float(state["t"]),
            "version": float(state["version"]),
        })
        if deadline is not None:
            metrics["dropped"] = float(state.get("timeouts_pending", 0))
            state["timeouts_pending"] = 0
        return metrics

    def _deliver_until_aggregate(state):
        """Advance simulated time, delivering finished clients in slot
        order, until one aggregation fires.  Returns its metrics."""
        while True:
            pending = [i for i, s in enumerate(state["slots"])
                       if s is not None]
            if not pending:
                return None  # nothing in flight: caller must dispatch
            state["t"] = max(state["t"],
                             min(state["slots"][i]["finish_t"]
                                 for i in pending))
            for i in pending:
                s = state["slots"][i]
                if s is None or s["finish_t"] > state["t"]:
                    continue
                if s.get("timed_out"):
                    # deadline straggler: the slot frees, nothing lands
                    state["slots"][i] = None
                    state["timeouts_pending"] = \
                        state.get("timeouts_pending", 0) + 1
                    streak = state.get("timeout_streak", 0) + 1
                    state["timeout_streak"] = streak
                    if streak > 10 * n:
                        raise RuntimeError(
                            f"async deadline faults: {streak} consecutive "
                            "timeouts with no delivery -- deadline "
                            f"{deadline:g} starves the buffer")
                    continue
                state["timeout_streak"] = 0
                new_cs, upload, pm, ef_row = s["payload"]
                c = jnp.int32(s["client"])
                if jax.tree.leaves(state["clients"]):
                    state["clients"] = _scatter_row(state["clients"], c,
                                                    new_cs)
                state["pms"] = _scatter_row(state["pms"], c, pm)
                if stateful:
                    state["ef"] = _scatter_row(state["ef"], c, ef_row)
                state["buffer"].append({
                    "upload": upload,
                    "staleness": state["version"] - s["version"],
                    "metrics": s["metrics"],
                })
                state["slots"][i] = None
                if len(state["buffer"]) >= acfg.buffer_size:
                    # finishers still in their slots deliver on a later
                    # pass, carrying post-bump (larger) staleness
                    return _aggregate(state)
            _dispatch(state)

    def async_round(state):
        if stateful and "ef" not in state:
            # same guard as engine.make_round_body: fail with the
            # contract, not a deep pytree mismatch inside the dispatch
            raise ValueError(
                f"compressor {compressor.name!r} carries error-feedback "
                "residuals: init the state with the same compressor "
                "(init_async_state(..., compressor=...))")
        state = dict(state, slots=list(state["slots"]),
                     buffer=list(state["buffer"]))
        while True:
            _dispatch(state)
            metrics = _deliver_until_aggregate(state)
            if metrics is not None:
                return state, metrics

    # the jitted pieces the host-side driver launches, exposed so tooling
    # (benchmarks/round_engine.py's peak-memory probe) can AOT-lower them
    # with representative shapes; the driver itself stays host-side
    async_round.jitted_parts = {
        "train_cohort": train_cohort,
        "agg_plain": agg_mesh_plain if mesh_placed else agg_plain,
        "agg_weighted": agg_mesh_weighted if mesh_placed else agg_weighted,
    }
    return async_round
