"""Placement-pluggable cohort engine: ONE round executor for every regime.

The paper's structural property -- tau local alternating-SGD steps with
zero cross-client traffic, then a single delta-mean all-reduce -- is the
same round body whether the cohort lives on one device or across a mesh.
This module owns that body (sample -> gather -> tau-scan local rounds ->
scatter -> aggregate) and parameterizes WHERE the cohort axis runs via a
``Placement``:

  * ``VmapPlacement``  -- today's single-device simulation: the cohort is
    a ``jax.vmap`` leading axis; the delta-mean is a tree mean.  This is
    the bit-for-bit path ``make_round_fn`` has always produced.
  * ``MeshPlacement``  -- the datacenter regime: the cohort dim is mapped
    onto the mesh's client axis (``mesh_roles(mesh).client``) through
    ``compat.shard_map``; the strategy's delta-mean lowers to the round's
    ONE cross-client ``psum`` (metric scalars ride in the same collective);
    client/pms stores are laid out with ``NamedSharding``s derived from
    ``sharding/rules.py`` so the ``n_clients x params`` buffers are
    actually distributed over the client axis.

The sync regime (``rounds.make_round_fn``) is a thin wrapper over
``make_cohort_round``; the async regime (``async_rounds``) drives its
dispatch cohorts through ``Placement.cohort_map`` and shares the rng
split layout, batch draw, and scatter helpers below, so all three
regimes execute the identical per-client body.

Constraints of the mesh placement (checked at construction):

  * ``m_sampled`` must divide evenly over the client axis (each shard
    trains ``m / axis_size`` cohort lanes); the async regime's
    variable-size dispatch cohorts instead PAD to the next multiple with
    masked lanes (``cohort_map``/``pad_cohort``), sliced away on exit;
  * the client *store* axis (``n_clients``) falls back to replicated when
    it does not divide the client axis (``sharding/rules.py`` semantics)
    -- the round still runs, only the store layout degrades.

On a 1-device mesh the mesh placement reproduces the vmap placement
bitwise on CPU (the psum over a size-1 axis is an identity and the
mean-of-local-means divides by 1.0 exactly); on k>1 shards the delta-mean
associates as mean-of-local-means, equal to the flat mean up to f32
summation order (tolerance recorded in DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.strategies import LocalWeights, Strategy, tmap
from repro.faults.inject import (attack_round_key, corrupt_payload,
                                 fault_draws, fault_round_keys,
                                 needs_attack_key, screen_upload,
                                 wire_corruptor)
from repro.robust.reducers import (bucket_finish, bucket_partials,
                                   pack_cohort, robust_reduce)

Pytree = Any


# ---------------------------------------------------------------------------
# shared round-body pieces (both regimes, every placement)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SimConfig:
    n_clients: int
    m_sampled: int
    tau: int
    batch_size: int
    seed: int = 0

    @property
    def p(self) -> float:
        return self.m_sampled / self.n_clients


# fold_in salt deriving the comm layer's per-round key from k_batch:
# a pure function of an existing key, so adding compression perturbs
# neither the cohort sample nor the batch draws (the identity-compressor
# bitwise-equivalence pin depends on this)
_COMM_SALT = 0xC0111


def comm_round_keys(k_batch, m: int) -> jax.Array:
    """Per-cohort-lane rng keys for stochastic compressors, derived from
    (not consuming) the round's batch key.  One definition: the sync
    round body and the async dispatcher both use it."""
    return jax.random.split(jax.random.fold_in(k_batch, _COMM_SALT), m)


def split_round_rng(rng) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """THE per-round rng split layout: (next_rng, k_select, k_batch).

    Every consumer -- the sync executor, the async dispatcher, and
    ``peek_sampled_clients`` -- goes through this one function, so the
    cohort a round will sample is predictable from the state alone."""
    rng, k_sel, k_batch = jax.random.split(rng, 3)
    return rng, k_sel, k_batch


def sample_cohort(k_sel, n: int, m: int, p=None) -> jax.Array:
    """Sample m of n clients without replacement (optionally masked by
    probability vector ``p`` -- the async regime's busy-client mask)."""
    if p is not None:
        return jax.random.choice(k_sel, n, (m,), replace=False, p=p)
    return jax.random.choice(k_sel, n, (m,), replace=False)


def draw_cohort_batches(data: Pytree, k_batch, idx: jax.Array, tau: int,
                        b: int) -> Pytree:
    """Per-cohort minibatch stacks: (m, tau, b, ...) drawn i.i.d. from each
    sampled client's rows."""
    n_i = jax.tree.leaves(data)[0].shape[1]
    bidx = jax.random.randint(k_batch, (idx.shape[0], tau, b), 0, n_i)
    return tmap(lambda t: jax.vmap(lambda i, bi: t[i][bi])(idx, bidx), data)


def broadcast_client_store(template: Pytree, n: int) -> Pytree:
    """Per-client store from a single-client template: leading n axis,
    materialized (the stores are scattered into every round).  Stateless
    strategies ({}) stay {}."""
    if not jax.tree.leaves(template):
        return {}
    return tmap(lambda t: jnp.broadcast_to(t, (n,) + t.shape).copy(),
                template)


def gather_client_state(clients: Pytree, idx: jax.Array) -> Pytree:
    """Rows ``idx`` of the client store; {} for stateless strategies --
    the one empty-client-state path for every regime.  A virtual store
    (``core.store.VirtualStore``) gathers host-side and streams the rows
    to device; the dense path is trace-identical to before."""
    if hasattr(clients, "gather_rows"):
        return clients.gather_rows(idx)
    if not jax.tree.leaves(clients):
        return {}
    return tmap(lambda t: t[idx], clients)


def scatter_cohort_rows(store: Pytree, idx, new: Pytree) -> Pytree:
    """``store.at[idx].set(new)`` over the tree; {} passes through.  THE
    scatter both regimes trace (the donated jit wrapper for eager callers
    is ``scatter_client_rows``)."""
    if not jax.tree.leaves(store):
        return store
    return tmap(lambda all_, nw: all_.at[idx].set(nw), store, new)


@partial(jax.jit, donate_argnums=(0,))
def scatter_client_rows(store: Pytree, idx, new: Pytree) -> Pytree:
    """Donated-jit ``scatter_cohort_rows``: the ``n_clients x params``
    buffer updates in place instead of being copied per call (the async
    regime's eager delivery path)."""
    return scatter_cohort_rows(store, idx, new)


def _personal_model(strategy: Strategy, x, cs, upload):
    if strategy.name == "feddeper":
        return cs["v"]
    if strategy.name == "scaffold":
        return tmap(jnp.add, x, upload["dv"])
    return tmap(jnp.add, x, upload)


def make_per_client(strategy: Strategy, grad_fn, compressor=None,
                    faults=None) -> Callable:
    """The per-client round body every placement maps over the cohort
    axis: tau local steps + the personal-model view of the result.

    With a ``compressor`` (``repro.comm``) the body grows two operands --
    the client's error-feedback residual row and a per-lane rng key --
    and one output (the new residual): the upload is compressed and
    DECOMPRESSED here, inside the per-client lane, so the aggregate (and
    under the mesh placement the round's single psum) always sees a
    dense cohort stack.  The personal model is taken from the RAW upload
    first: the client keeps its own uncompressed delta; only the wire
    copy is lossy.

    With ``faults`` (an ACTIVE ``repro.faults.FaultConfig``) the body
    grows two more trailing operands -- the client's pre-round pms row
    and a per-lane fault key -- and one more trailing output: the lane's
    screening weight in [0, 1].  Fault order models the physical path:
    train -> take the personal model from the RAW (pre-wire) upload ->
    compress -> corrupt (bit-flips hit the compressed wire codes via
    ``Compressor.roundtrip(corrupt=...)``; Byzantine/non-finite modes hit
    the decoded payload) -> server-side screening zeroes the weight AND
    the values of dropped/non-finite lanes.  A dropped client never ran:
    its cs/pms/ef rows revert to the pre-round values, so the scatter
    writes back exactly what was there.

    A STEALTH corrupt mode (``faults.STEALTH_MODES``) adds one final
    BROADCAST operand -- the round's shared attack key -- so colluding
    lanes coordinate without any cross-lane traffic; non-stealth fault
    traces stay byte-identical to before."""
    stealth = needs_attack_key(faults)

    def per_client(x_i, ctx_i, cs_i, batches_i):
        new_cs, upload, metrics = strategy.local_round(
            x_i, ctx_i, cs_i, batches_i, grad_fn)
        pm = _personal_model(strategy, x_i, new_cs, upload)
        return new_cs, upload, pm, metrics

    if compressor is None and faults is None:
        return per_client

    def per_client_comm(x_i, ctx_i, cs_i, batches_i, ef_i, key_i):
        new_cs, upload, pm, metrics = per_client(x_i, ctx_i, cs_i,
                                                 batches_i)
        upload, new_ef, cm = compressor.roundtrip(upload, ef_i, key_i)
        return new_cs, upload, pm, {**metrics, **cm}, new_ef

    if faults is None:
        return per_client_comm

    def per_client_faulty(x_i, ctx_i, cs_i, batches_i, *rest):
        akey = None
        if stealth:
            rest, akey = rest[:-1], rest[-1]
        if compressor is not None:
            ef_i, key_i, pm_old_i, fkey_i = rest
        else:
            pm_old_i, fkey_i = rest
        new_cs, upload, pm, metrics = per_client(x_i, ctx_i, cs_i,
                                                 batches_i)
        dropped, corrupted, k_pay = fault_draws(faults, fkey_i)
        ef_new = None
        if compressor is not None:
            upload, ef_new, cm = compressor.roundtrip(
                upload, ef_i, key_i,
                corrupt=wire_corruptor(faults, corrupted, k_pay))
            metrics = {**metrics, **cm}
        if compressor is None or faults.corrupt_mode != "bitflip":
            upload = corrupt_payload(faults, upload, corrupted, k_pay,
                                     akey=akey)
        upload, w_i, fm = screen_upload(faults, upload, dropped)
        revert = lambda old, new: tmap(
            lambda o, n: jnp.where(dropped, o, n), old, new)
        new_cs = revert(cs_i, new_cs)
        pm = revert(pm_old_i, pm)
        metrics = {**metrics, **fm}
        if compressor is not None:
            return (new_cs, upload, pm, metrics,
                    revert(ef_i, ef_new), w_i)
        return new_cs, upload, pm, metrics, w_i

    return per_client_faulty


def make_dispatch_cohort(strategy: Strategy, grad_fn, placement,
                         compressor=None) -> Callable:
    """The cohort-mapped per-client body the async regime launches per
    dispatch: EVERY operand carries the cohort axis (each client trains
    against its own pulled snapshot), so there is no aggregate and no
    collective -- just ``Placement.cohort_map`` over ``make_per_client``.
    The sync round body maps the same per-client function with a shared
    broadcast model instead (``Placement.execute``)."""
    n_args = 6 if compressor is not None else 4
    return placement.cohort_map(
        make_per_client(strategy, grad_fn, compressor),
        in_axes=(0,) * n_args)


# ---------------------------------------------------------------------------
# placements
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class VmapPlacement:
    """Single-device cohort: vmap leading axis, tree-mean aggregate.
    Bit-for-bit the historical ``make_round_fn`` path."""

    name = "vmap"

    def check(self, sim: SimConfig) -> None:
        pass

    def cohort_map(self, fn, in_axes) -> Callable:
        return jax.vmap(fn, in_axes=in_axes)

    def place_state(self, state: Pytree) -> Pytree:
        return state

    def constrain_store(self, store: Pytree) -> Pytree:
        return store

    def execute(self, strategy: Strategy, x, server, ctx, cs, batches,
                grad_fn, p: float, compressor=None, ef=None, keys=None,
                faults=None, pms=None, fkeys=None, robust=None,
                akey=None):
        per_client = make_per_client(strategy, grad_fn, compressor,
                                     faults)
        args, axes = [x, ctx, cs, batches], [None, None, 0, 0]
        if compressor is not None:
            args += [ef, keys]
            axes += [0, 0]
        if faults is not None:
            args += [pms, fkeys]
            axes += [0, 0]
        if akey is not None:
            args += [akey]
            axes += [None]
        out = jax.vmap(per_client, in_axes=tuple(axes))(*args)
        w = None
        if faults is not None:
            w, out = out[-1], out[:-1]
        if compressor is not None:
            new_cs, uploads, pms_new, metrics, ef_new = out
        else:
            (new_cs, uploads, pms_new, metrics), ef_new = out, {}
        mean_kw = {}
        if robust is not None:
            mean_kw["mean_fn"] = _robust_mean_fn(robust)
        if faults is None:
            x2, server2, agg_metrics = strategy.aggregate(x, server,
                                                          uploads, p,
                                                          **mean_kw)
        else:
            x2, server2, agg_metrics = strategy.aggregate(
                x, server, uploads, p, weights=w, **mean_kw)
        metrics = {k: v.mean() for k, v in metrics.items()}
        metrics.update(agg_metrics)
        return new_cs, pms_new, x2, server2, metrics, ef_new


def _robust_mean_fn(robust) -> Callable:
    """The vmap placement's robust mean: the whole (m, ...) upload stack
    is on one device, so the reducer (``repro.robust.robust_reduce``)
    runs directly -- screening weights (raw (m,) array or LocalWeights)
    become the reducer's lane weights, uniform ones otherwise.  Passed
    as ``mean_fn`` so ``strategies.resolve_mean`` composition (and the
    EXACTLY-ONCE contract: Scaffold's whole {dv, dc} dict arrives in one
    call) is untouched.  Reduced leaves come back f32, same as the mesh
    psum path."""
    def mean_fn(tree: Pytree, weights=None) -> Pytree:
        m = jax.tree.leaves(tree)[0].shape[0]
        if weights is None:
            w = jnp.ones((m,), jnp.float32)
        elif isinstance(weights, LocalWeights):
            w = weights.w
        else:
            w = jnp.asarray(weights, jnp.float32)
        return robust_reduce(robust, tree, w)

    return mean_fn


def _psum_mean_fn(axis: str, metrics_local: Dict[str, jax.Array],
                  box: Dict, axis_size: int, robust=None) -> Callable:
    """The mean ``strategy.aggregate`` lowers to psum under shard_map:
    mean over the local cohort lanes, then ONE ``pmean`` across the client
    axis.  The per-round metric scalars are bundled into the same psum so
    the whole round has exactly one cross-client collective; the reduced
    metrics come back through ``box`` (the aggregate's signature has no
    metrics channel).

    ``weights`` (optional kwarg, the FULL cohort weight vector --
    replicated, NOT sharded, across the client axis) lowers the
    staleness-weighted mean into the same single collective.  Because
    every shard holds the whole vector, the global weight sum, the
    zero-weight-sum guard, and the normalization are shard-local
    arithmetic (identical ops to ``strategies.tree_weighted_mean``, so
    a 1-device mesh reproduces it bitwise); each shard then slices its
    own lanes' normalized weights by ``axis_index``, contributes a
    weighted partial sum, and ONE psum of (partials, metrics) finishes
    the mean -- the weighted upload-sum and the (pre-normalized) weight
    sum ride the same collective the uniform path already uses.
    ``axis_size`` is passed statically: ``lax.axis_size`` spells as a
    second psum on jax 0.4.x (compat.py), which would break the
    one-collective contract.

    A ``strategies.LocalWeights`` (the faults layer's SHARD-LOCAL
    screening weights -- each shard only knows its own lanes' weights)
    takes a third branch: weighted partial sum over the local lanes,
    then ONE psum of (partials, local weight sum, metrics) -- the global
    weight sum rides the same collective -- and a shard-local divide.
    The divide-after-psum associates differently from the vmap path's
    normalize-then-dot (atol 1e-6, DESIGN.md §10); all-zero surviving
    mass degrades to a zero delta, which equals the uniform mean of the
    screened (zero-valued) lanes.  The psum-ed weight sum is recorded on
    the LocalWeights for Scaffold's p_eff -- still one collective.

    ``robust`` (a ``repro.robust.RobustConfig``) swaps the mean for a
    robust reducer.  None is the bitwise default (this function's body
    above is untouched).  The declared collective budget per mode:

      * gather modes (trimmed/median/krum) need cross-client ORDER
        information, so every upload leaf + the lane weights are packed
        into ONE flat f32 buffer and ONE ``all_gather`` replicates the
        full stack; each shard then runs the identical reducer on
        identical data (deterministic => replicated result, no second
        collective), and the metrics ride ONE scalar psum.  Budget:
        1 all_gather + 1 psum, jaxpr-counted.
      * bucket mode pre-aggregates lanes into B buckets by LINEAR
        weighted partial sums, which therefore ride the round's ONE
        psum alongside the local weight sum and metrics (same bundling
        as the LocalWeights branch); the cheap inner reduce over the B
        replicated bucket means is shard-local.  Budget: 1 psum --
        O(1) cross-client data movement, same as the plain mean.

    ``weights`` may be None (uniform), or the faults layer's shard-local
    ``LocalWeights`` (its global sum is recovered from the gathered /
    psum-ed weights for Scaffold's p_eff -- no extra collective).  The
    async regime's replicated weight vector never reaches the robust
    path (``--robust`` is sync-only, guarded at the CLI)."""
    def robust_fn(tree: Pytree, weights) -> Pytree:
        leaves = jax.tree.leaves(tree)
        m_local = leaves[0].shape[0]
        lw = None
        if weights is None:
            w_local = jnp.ones((m_local,), jnp.float32)
        elif isinstance(weights, LocalWeights):
            lw, w_local = weights, weights.w
        else:
            raise NotImplementedError(
                "robust aggregation expects shard-local weights "
                "(LocalWeights) or none; the async regime's replicated "
                "weight vector is not supported")
        if robust.mode == "bucket":
            lane0 = jax.lax.axis_index(axis) * m_local
            sums, wsum = bucket_partials(robust, tree, w_local, lane0)
            sums, wsum, ws, msum = jax.lax.psum(
                (sums, wsum, w_local.sum(), metrics_local), axis)
            if lw is not None:
                lw.set_global_sum(ws)
            box["metrics"] = {k: v / axis_size for k, v in msum.items()}
            return bucket_finish(robust, sums, wsum)
        buf, unpack = pack_cohort(tree, w_local)
        full = jax.lax.all_gather(buf, axis, axis=0, tiled=True)
        tree_full, w_full = unpack(full)
        if lw is not None:
            lw.set_global_sum(w_full.sum())
        msum = jax.lax.psum(metrics_local, axis)
        box["metrics"] = {k: v / axis_size for k, v in msum.items()}
        return robust_reduce(robust, tree_full, w_full)

    def mean_fn(tree: Pytree, weights=None) -> Pytree:
        if robust is not None:
            return robust_fn(tree, weights)
        if weights is None:
            local = tmap(lambda t: t.mean(0), tree)
            reduced, box["metrics"] = jax.lax.pmean((local, metrics_local),
                                                    axis)
            return reduced
        if isinstance(weights, LocalWeights):
            w_local = weights.w
            part = tmap(lambda t: jnp.tensordot(
                w_local, t.astype(jnp.float32), axes=(0, 0)), tree)
            reduced, wsum, msum = jax.lax.psum(
                (part, w_local.sum(), metrics_local), axis)
            weights.set_global_sum(wsum)
            safe = jnp.where(wsum > 0, wsum, 1.0)
            reduced = tmap(lambda t: t / safe, reduced)
            box["metrics"] = {k: v / axis_size for k, v in msum.items()}
            return reduced
        w = jnp.asarray(weights, jnp.float32)
        s = w.sum()
        safe = jnp.where(s > 0, s, 1.0)
        wn = jnp.where(s > 0, w / safe, 1.0 / w.shape[0])
        m_local = w.shape[0] // axis_size
        start = jax.lax.axis_index(axis) * m_local
        wn_i = jax.lax.dynamic_slice(wn, (start,), (m_local,))
        part = tmap(lambda t: jnp.tensordot(wn_i, t.astype(jnp.float32),
                                            axes=(0, 0)), tree)
        reduced, msum = jax.lax.psum((part, metrics_local), axis)
        box["metrics"] = {k: v / axis_size for k, v in msum.items()}
        return reduced

    return mean_fn


def pad_cohort(tree: Pytree, k: int, mode: str = "edge"
               ) -> Tuple[Pytree, int]:
    """Pad every leaf's leading cohort axis up to the next multiple of
    ``k`` (the client-axis size).  Returns ``(padded, n_real)``.

    ``mode='edge'`` repeats the last real lane -- dispatch padding, where
    the masked lanes must run real finite math through the tau-scan (their
    outputs are sliced away, and there is no collective on the dispatch
    path for garbage to leak through).  ``mode='zero'`` appends zero
    lanes -- aggregation padding, where the lanes carry zero WEIGHT and
    zero-valued uploads keep the masked products finite (0 * 0)."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return tree, 0
    f = leaves[0].shape[0]
    pad = (-f) % k
    if pad == 0:
        return tree, f

    def one(t):
        if mode == "edge":
            fill = jnp.broadcast_to(t[-1:], (pad,) + t.shape[1:])
        else:
            fill = jnp.zeros((pad,) + t.shape[1:], t.dtype)
        return jnp.concatenate([t, fill.astype(t.dtype)], axis=0)

    return tmap(one, tree), f


@dataclass(frozen=True)
class MeshPlacement:
    """Datacenter cohort: the cohort dim lives on the mesh's client axis.

    ``shard_map`` wraps the per-client map + aggregate; each shard runs
    ``m / axis_size`` cohort lanes with ZERO cross-client traffic through
    the tau-scan, then the delta-mean psum is the round's single
    collective.  Stores are constrained to ``sharding/rules.param_specs``
    layouts (client axis on dim 0 when ``n_clients`` divides, trailing
    dims per the parameter rules)."""

    mesh: Mesh
    roles: Any = None  # MeshRoles; resolved from the mesh when None

    name = "mesh"

    def __post_init__(self):
        if self.roles is None:
            from repro.launch.mesh import mesh_roles
            object.__setattr__(self, "roles", mesh_roles(self.mesh))

    @property
    def client_axis(self) -> str:
        return self.roles.client

    @property
    def axis_size(self) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return sizes[self.client_axis]

    def check(self, sim: SimConfig) -> None:
        k = self.axis_size
        if sim.m_sampled % k:
            raise ValueError(
                f"mesh placement: m_sampled={sim.m_sampled} must divide "
                f"evenly over the client axis {self.client_axis!r} "
                f"(size {k})")

    def _store_specs(self, store: Pytree) -> Pytree:
        from repro.sharding.rules import param_specs
        return param_specs(store, self.mesh, model=self.roles.model,
                           fsdp=self.roles.fsdp, client=self.client_axis)

    def state_specs(self, state: Pytree) -> Pytree:
        """NamedSharding pytree for a full sim state: client/pms stores
        over the client axis (replicated fallback when n_clients does not
        divide it), everything else replicated.  This is THE carry layout
        contract: ``place_state`` materializes it, and the scan-block
        driver relies on the round body re-pinning its outputs to the same
        specs (``constrain_store``) so the carry never reshards between
        scanned rounds."""
        from repro.sharding.rules import sim_state_specs
        return sim_state_specs(state, self.mesh, client=self.client_axis,
                               model=self.roles.model, fsdp=self.roles.fsdp)

    def place_state(self, state: Pytree) -> Pytree:
        """Lay the state out on the mesh per ``state_specs``.  Virtual
        stores (host-side backing tiers, ``core.store``) pass through
        untouched -- only their gathered working-set rows ever get a
        device layout, via the same specs on the cohort-sized carry."""
        virt = {k: v for k, v in state.items()
                if hasattr(v, "gather_rows")}
        if virt:
            rest = {k: v for k, v in state.items() if k not in virt}
            placed = jax.tree.map(jax.device_put, rest,
                                  self.state_specs(rest))
            return {**placed, **virt}
        return jax.tree.map(jax.device_put, state,
                            self.state_specs(state))

    def constrain_store(self, store: Pytree) -> Pytree:
        """Pin a scattered store to its rules-derived layout inside jit,
        so the round's output keeps the distributed layout its input had
        (donation then reuses the sharded buffers)."""
        if not jax.tree.leaves(store):
            return store
        return tmap(jax.lax.with_sharding_constraint, store,
                    self._store_specs(store))

    def cohort_map(self, fn, in_axes) -> Callable:
        """Map ``fn`` over a cohort axis distributed over the client axis
        (no collective: the async dispatch path).  ``in_axes`` follows
        vmap conventions restricted to 0 | None.

        Cohort sizes that do not divide the client axis are PADDED up to
        the next multiple with masked lanes (the last real lane repeated
        -- ``pad_cohort(mode='edge')``) and every output's leading axis
        is sliced back to the real size, so the padding is invisible to
        callers.  This is what lets the async regime's variable-size
        refill dispatches run on a mesh (they rarely divide the axis
        under heterogeneous delays); as a side effect it also caps
        retracing at one compile per padded size (multiples of the axis)
        instead of one per distinct cohort size."""
        axis = self.client_axis
        k = self.axis_size
        specs = tuple(P(axis) if a == 0 else P() for a in in_axes)

        def mapped(*args):
            f = None
            for a, arg in zip(in_axes, args):
                leaves = jax.tree.leaves(arg)
                if a == 0 and leaves:
                    f = leaves[0].shape[0]
                    break
            pad = 0 if f is None else (-f) % k
            if pad:
                args = tuple(pad_cohort(arg, k)[0] if a == 0 else arg
                             for a, arg in zip(in_axes, args))

            def body(*shard_args):
                local_axes = tuple(0 if a == 0 else None for a in in_axes)
                return jax.vmap(fn, in_axes=local_axes)(*shard_args)

            out = shard_map(body, mesh=self.mesh, in_specs=specs,
                            out_specs=P(axis))(*args)
            if pad:
                out = tmap(lambda t: t[:f], out)
            return out

        return mapped

    def _aggregate_tail(self, strategy, x, server, uploads, metrics, p,
                        weights=None, robust=None):
        """The shard-local aggregate: cohort-lane metric means + the
        strategy's aggregate with the delta-mean lowered to the round's
        ONE cross-client psum (metric scalars ride the same collective).
        ``weights`` (a ``LocalWeights``, the faults layer's shard-local
        screening weights) lowers screened aggregation into that same
        psum.  ``robust`` swaps the mean for a robust reducer within its
        declared collective budget (``_psum_mean_fn``)."""
        axis = self.client_axis
        metrics_local = {k: v.mean() for k, v in metrics.items()}
        box: Dict = {}
        x2, server2, agg_metrics = strategy.aggregate(
            x, server, uploads, p, weights=weights,
            mean_fn=_psum_mean_fn(axis, metrics_local, box,
                                  self.axis_size, robust))
        # a strategy that never called mean_fn still needs its metric
        # scalars reduced (costs a second, scalar-sized collective)
        metrics_global = box.get("metrics")
        if metrics_global is None:
            metrics_global = jax.lax.pmean(metrics_local, axis)
        metrics_global = dict(metrics_global)
        metrics_global.update(agg_metrics)
        return x2, server2, metrics_global

    def place_uploads(self, uploads: Pytree) -> Pytree:
        """Lay a stacked upload buffer out over the client axis
        (``sharding/rules.upload_stack_specs``) before handing it to
        ``aggregate_buffer``: the host-side ``jnp.stack`` otherwise
        commits every lane to one device and the shard_map entry pays a
        scatter it could have amortized into the transfer."""
        from repro.sharding.rules import upload_stack_specs
        return jax.tree.map(jax.device_put, uploads, upload_stack_specs(
            uploads, self.mesh, client=self.client_axis,
            model=self.roles.model, fsdp=self.roles.fsdp))

    def aggregate_buffer(self, strategy: Strategy, x, server, uploads,
                         p: float, weights=None):
        """One buffered aggregation lowered to a single cross-client
        psum: the async regime's (staleness-weighted) aggregate on the
        mesh.  ``uploads`` is an (m, ...) stack with m a multiple of the
        client axis -- callers pad short buffers with zero-valued,
        zero-WEIGHT lanes (``pad_cohort(mode='zero')``) and pass ``p``
        consistent with the padded m (the zero weights make the padding
        massless; see ``Scaffold.aggregate``).  ``weights`` is the FULL
        (m,) weight vector, deliberately replicated (in_spec ``P()``) so
        every shard normalizes and zero-sum-guards it locally without a
        second collective (``_psum_mean_fn``).  Returns
        ``(x, server, agg_metrics)``."""
        axis = self.client_axis
        c = P(axis)
        box: Dict = {}
        mean_fn = _psum_mean_fn(axis, {}, box, self.axis_size)

        if weights is None:
            def body(x, server, uploads):
                return strategy.aggregate(x, server, uploads, p,
                                          mean_fn=mean_fn)

            return shard_map(body, mesh=self.mesh, in_specs=(P(), P(), c),
                             out_specs=(P(), P(), P()))(x, server, uploads)

        def body_w(x, server, uploads, w):
            return strategy.aggregate(x, server, uploads, p, weights=w,
                                      mean_fn=mean_fn)

        return shard_map(body_w, mesh=self.mesh,
                         in_specs=(P(), P(), c, P()),
                         out_specs=(P(), P(), P()))(x, server, uploads,
                                                    weights)

    def execute(self, strategy: Strategy, x, server, ctx, cs, batches,
                grad_fn, p: float, compressor=None, ef=None, keys=None,
                faults=None, pms=None, fkeys=None, robust=None,
                akey=None):
        # compressed round: the per-client lane compresses AND
        # decompresses its upload (repro.comm contract), so the psum in
        # the aggregate tail still reduces a dense stack -- compression
        # adds no collective.  Faulty round: screening happens per-lane
        # too (shard-local weights, zeroed bad values), and the weight
        # vector lowers into the SAME psum via LocalWeights -- faults
        # add no collective either.  A stealth attack key is BROADCAST
        # (in_spec P()): colluders coordinate through the shared key,
        # not through traffic.  ``robust`` swaps the aggregate-tail mean
        # for a robust reducer inside its declared collective budget.
        c = P(self.client_axis)
        per_client = make_per_client(strategy, grad_fn, compressor,
                                     faults)
        lane_args = [cs, batches]
        if compressor is not None:
            lane_args += [ef, keys]
        if faults is not None:
            lane_args += [pms, fkeys]
        n_lane = len(lane_args)
        n_bcast = 0 if akey is None else 1
        if n_bcast:
            lane_args += [akey]
        m_global = jax.tree.leaves(batches)[0].shape[0]

        def body(x, server, ctx, *lanes):
            out = jax.vmap(per_client,
                           in_axes=(None, None) + (0,) * n_lane
                           + (None,) * n_bcast)(
                x, ctx, *lanes)
            w = None
            if faults is not None:
                w, out = LocalWeights(out[-1], m_global), out[:-1]
            if compressor is not None:
                new_cs, uploads, pms_new, metrics, ef_new = out
            else:
                new_cs, uploads, pms_new, metrics = out
            x2, server2, metrics_global = self._aggregate_tail(
                strategy, x, server, uploads, metrics, p, weights=w,
                robust=robust)
            if compressor is not None:
                return new_cs, pms_new, x2, server2, metrics_global, ef_new
            return new_cs, pms_new, x2, server2, metrics_global

        in_specs = (P(), P(), P()) + (c,) * n_lane + (P(),) * n_bcast
        out_specs = (c, c, P(), P(), P())
        if compressor is not None:
            out_specs = out_specs + (c,)
        sm_kw = {}
        if robust is not None and robust.gathers:
            # the gather modes' reduced model IS replicated -- every
            # shard runs the identical deterministic reducer over the
            # identical gathered stack -- but jax's rep-checker cannot
            # infer replication through all_gather, so the static check
            # is disabled for exactly these modes (the subprocess
            # equivalence tests pin the actual replication)
            sm_kw["check_rep"] = False
        out = shard_map(body, mesh=self.mesh, in_specs=in_specs,
                        out_specs=out_specs, **sm_kw)(
            x, server, ctx, *lane_args)
        if compressor is None:
            out = out + ({},)
        return out


def make_placement(name: str, mesh: Optional[Mesh] = None):
    """'vmap' -> VmapPlacement(); 'mesh' -> MeshPlacement over ``mesh``
    (default: all local devices on the client axis)."""
    if name == "vmap":
        return VmapPlacement()
    if name == "mesh":
        if mesh is None:
            from repro.launch.mesh import make_client_mesh
            mesh = make_client_mesh()
        return MeshPlacement(mesh)
    raise ValueError(f"unknown placement {name!r} (want 'vmap' | 'mesh')")


# ---------------------------------------------------------------------------
# the cohort executor
# ---------------------------------------------------------------------------

def init_ef_store(strategy: Strategy, x: Pytree, n_clients: int,
                  compressor, layout=None) -> Pytree:
    """The error-feedback residual store a stateful compressor carries:
    ``n_clients`` f32 zero rows shaped like one client's upload
    (``strategy.upload_template``).  {} for stateless compressors --
    the state pytree then has no ``ef`` entry at all, keeping the
    uncompressed trace byte-identical.  ``layout`` (core.store) picks
    dense rows vs a virtual backing tier."""
    if compressor is None or not compressor.stateful:
        return {}
    from repro.core.store import resolve_layout
    tmpl = compressor.init_residual(strategy.upload_template(x))
    return resolve_layout(layout).init_store(tmpl, n_clients)


def init_cohort_state(sim: SimConfig, strategy: Strategy, x: Pytree,
                      placement=None, compressor=None,
                      layout=None) -> Pytree:
    """Full simulation state pytree.  ``x`` is copied: the state owns
    every buffer it holds, so donating rounds never invalidate caller-held
    params.  A mesh placement lays the stores out over the client axis.
    A stateful ``compressor`` (repro.comm, e.g. top-k with error
    feedback) adds the ``n_clients x upload`` residual store ``ef``,
    laid out/donated exactly like the client/pms stores.  ``layout``
    (core.store.make_layout spec) chooses dense stores (default,
    bit-for-bit the historical state) or virtual backing tiers whose
    rows only reach the device per-cohort."""
    from repro.core.store import resolve_layout
    layout = resolve_layout(layout)
    x = tmap(jnp.copy, x)
    clients = layout.init_store(strategy.client_init(x), sim.n_clients)
    # personalized-model store (Fig. 7): last local model per client
    pms = layout.init_store(x, sim.n_clients)
    state = {
        "x": x,
        "clients": clients,
        "pms": pms,
        "server": strategy.server_init(x),
        "rng": jax.random.PRNGKey(sim.seed),
        "round": jnp.zeros((), jnp.int32),
    }
    ef = init_ef_store(strategy, x, sim.n_clients, compressor, layout)
    if jax.tree.leaves(ef):
        state["ef"] = ef
    if placement is not None:
        state = placement.place_state(state)
    return state


def make_round_body(sim: SimConfig, strategy: Strategy, grad_fn,
                    data: Dict[str, jax.Array], placement=None,
                    compressor=None, faults=None,
                    robust=None) -> Callable:
    """The UN-jitted round body ``body(state) -> (state, metrics)``:
    sample -> gather -> local rounds -> scatter -> aggregate with the
    cohort axis placed per ``placement``.  Everything -- rng splitting,
    cohort sampling, batch draws -- is in-graph, so the body composes:
    ``make_cohort_round`` jits it directly (one call per round) and
    ``make_block_fn`` scans it (one call per R rounds).

    ``compressor`` (repro.comm) compresses each client's upload on the
    wire: the comm rng key is folded out of (never drawn from) the round
    key stream, so the sample/batch draws -- and with the identity
    compressor the whole trajectory -- match the uncompressed body
    bitwise.  A stateful compressor's residual rows ride the state's
    ``ef`` store: gathered with the cohort, scattered back, layout-pinned
    like the client/pms stores (so the scan carry and donation work
    unchanged).

    ``faults`` (repro.faults.FaultConfig) injects per-lane dropouts and
    corrupted uploads and screens them server-side; the per-lane fault
    key derives from k_batch through a second fold_in salt, so the fault
    schedule is deterministic per (seed, round) and independent of every
    other stream.  An INACTIVE config (fault_rate=0, clip off) is
    normalized to None here: the fault-free program is traced, so
    fault_rate=0 stays bitwise-equal to today's trace on both
    placements.

    ``robust`` (repro.robust.RobustConfig, or a spec string) swaps the
    aggregate's mean for a robust reducer on every placement; None (or
    'none') traces the exact historical program -- same normalization
    contract as ``faults``.  A stealth fault mode additionally threads
    the round's shared attack key (one broadcast operand, no
    collective) into the per-client lanes."""
    from repro.robust.reducers import make_robust
    placement = placement or VmapPlacement()
    placement.check(sim)
    if faults is not None and not faults.active:
        faults = None
    robust = make_robust(robust)
    if robust is not None:
        robust.check_cohort(sim.m_sampled)
    n, m, tau, b = (sim.n_clients, sim.m_sampled, sim.tau, sim.batch_size)
    stateful = compressor is not None and compressor.stateful

    def round_body(state):
        if stateful and "ef" not in state:
            raise ValueError(
                f"compressor {compressor.name!r} carries error-feedback "
                "residuals: init the state with the same compressor "
                "(init_cohort_state/init_sim_state(..., compressor=...))")
        rng, k_sel, k_batch = split_round_rng(state["rng"])
        idx = sample_cohort(k_sel, n, m)  # (m,)

        # gather sampled client state + their data
        cs = gather_client_state(state["clients"], idx)
        batches = draw_cohort_batches(data, k_batch, idx, tau, b)
        ctx = strategy.broadcast(state["x"], state["server"])

        comm_kw = {}
        if compressor is not None:
            comm_kw = dict(compressor=compressor,
                           ef=gather_client_state(state.get("ef", {}),
                                                  idx),
                           keys=comm_round_keys(k_batch, m))
        if faults is not None:
            comm_kw.update(faults=faults,
                           pms=gather_client_state(state["pms"], idx),
                           fkeys=fault_round_keys(k_batch, m))
            if needs_attack_key(faults):
                comm_kw["akey"] = attack_round_key(k_batch)
        if robust is not None:
            comm_kw["robust"] = robust
        new_cs, pms_new, x, server, metrics, ef_new = placement.execute(
            strategy, state["x"], state["server"], ctx, cs, batches,
            grad_fn, sim.p, **comm_kw)
        if faults is not None:
            # per-lane fractions -> whole-cohort counts for the train log
            metrics = dict(metrics)
            for k in ("screened", "dropped"):
                metrics[k] = metrics[k] * m

        # scatter per-client state back (store layout pinned so donation
        # reuses the distributed buffers under the mesh placement, and so
        # a scan carry keeps the layout it entered with)
        clients = placement.constrain_store(
            scatter_cohort_rows(state["clients"], idx, new_cs))
        pms = placement.constrain_store(
            scatter_cohort_rows(state["pms"], idx, pms_new))
        out = {
            "x": x, "clients": clients, "pms": pms, "server": server,
            "rng": rng, "round": state["round"] + 1,
        }
        if stateful:
            out["ef"] = placement.constrain_store(
                scatter_cohort_rows(state["ef"], idx, ef_new))
        return out, metrics

    return round_body


def make_cohort_round(sim: SimConfig, strategy: Strategy, grad_fn,
                      data: Dict[str, jax.Array], *, placement=None,
                      donate: bool = True, compressor=None, faults=None,
                      layout=None, robust=None):
    """The per-round executor: returns jitted ``round_fn(state) -> (state,
    metrics)``.

    ``placement=None`` (or ``VmapPlacement()``) is bit-for-bit the
    historical single-device ``make_round_fn``.  ``donate=True`` donates
    the state pytree into the jitted call -- the client/pms stores update
    in place; the passed-in state must not be reused afterwards.
    ``compressor`` compresses the uplink; ``faults`` injects + screens
    client faults; ``robust`` swaps the aggregate's mean for a robust
    reducer (see ``make_round_body``).  A virtual ``layout``
    (core.store) swaps in the host-backed executor: same contract, only
    cohort rows on device, trajectory bitwise-equal to dense."""
    from repro.core.store import make_virtual_round_fn, resolve_layout
    layout = resolve_layout(layout)
    if layout.virtual:
        return make_virtual_round_fn(
            sim, strategy, grad_fn, data, layout=layout,
            placement=placement, donate=donate, compressor=compressor,
            faults=faults, robust=robust)
    round_body = make_round_body(sim, strategy, grad_fn, data, placement,
                                 compressor, faults, robust)
    if donate:
        return jax.jit(round_body, donate_argnums=(0,))
    return jax.jit(round_body)


def make_block_fn(sim: SimConfig, strategy: Strategy, grad_fn,
                  data: Dict[str, jax.Array], *, block_size: int,
                  placement=None, donate: bool = True, compressor=None,
                  faults=None, layout=None, robust=None):
    """The multi-round executor: ``block_size`` rounds inside ONE jitted
    ``lax.scan``.  Returns ``block_fn(state) -> (state, metrics)`` where
    every metric scalar comes back stacked as a ``(block_size,)`` array
    (round r of the block at index r), so the host syncs -- and the
    dispatch/donation handoff happens -- once per block instead of once
    per round.

    RNG-stream contract: the scanned body is exactly the per-round body,
    with the state (including ``state['rng']``) as the scan carry, so the
    block splits the round keys identically to a host loop over
    ``make_cohort_round`` -- the two trajectories are bitwise-equal on
    CPU/TPU (tested for block_size in {1, 3, R}).  Under a mesh placement
    the carry threads the sharded client/pms stores through the scan
    without resharding (the body re-pins them via ``constrain_store``),
    keeping exactly one cross-client psum per round -- i.e. one psum in
    the scanned body, executed ``block_size`` times.

    Tradeoff: compile time grows with nothing (the body compiles once,
    scan-iterated), but eval/logging granularity becomes the block
    boundary -- drive it with ``rounds.run_blocks``."""
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    from repro.core.store import make_virtual_round_fn, resolve_layout
    layout = resolve_layout(layout)
    if layout.virtual:
        return make_virtual_round_fn(
            sim, strategy, grad_fn, data, layout=layout,
            placement=placement, donate=donate, compressor=compressor,
            faults=faults, block_size=block_size, robust=robust)
    round_body = make_round_body(sim, strategy, grad_fn, data, placement,
                                 compressor, faults, robust)

    def block_fn(state):
        def step(carry, _):
            return round_body(carry)

        return jax.lax.scan(step, state, None, length=block_size)

    if donate:
        return jax.jit(block_fn, donate_argnums=(0,))
    return jax.jit(block_fn)
