"""Simulation regime: n federated clients as a vmapped leading axis.

Reproduces the paper's experiments (n=10 cross-silo / n=100 cross-device,
client sampling, non-i.i.d splits) on a single host.  The whole round --
sampling, gather, tau local steps per selected client, scatter, aggregate
-- is one jitted function.

The round body itself lives in ``core/engine.py`` (the placement-pluggable
cohort executor); this module is the simulation-regime surface over it:
``make_round_fn`` with the default (vmap) placement is bit-for-bit the
historical single-device path, and ``placement=MeshPlacement(mesh)`` (or
``make_placement('mesh')``) runs the identical round with the cohort dim
distributed over the mesh's client axis.

Round buffers are DONATED by default (``make_round_fn(..., donate=True)``):
the state pytree -- dominated by the ``n_clients x params`` client/
personal-model stores -- is consumed by each jitted round call and its
buffers are reused for the output state, so the scatter updates in place
instead of doubling peak memory every round.  The contract that donation
imposes on callers: a state that has been passed to ``round_fn`` is dead
(its arrays are deleted); keep using only the returned state.
``init_sim_state`` defensively copies ``x`` so the caller's own params
survive round 1.  ``donate=False`` restores the copying behaviour
bit-for-bit (tested).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (  # noqa: F401  (re-exported regime surface)
    MeshPlacement,
    SimConfig,
    VmapPlacement,
    _personal_model,
    broadcast_client_store,
    gather_client_state,
    init_cohort_state,
    make_block_fn,
    make_cohort_round,
    make_placement,
    make_round_body,
    sample_cohort,
    scatter_client_rows,
    scatter_cohort_rows,
    split_round_rng,
)
from repro.core.strategies import Strategy, tmap

Pytree = Any


def init_sim_state(sim: SimConfig, strategy: Strategy, x: Pytree,
                   placement=None, compressor=None, layout=None):
    """Returns the full simulation state pytree.  ``x`` is copied: the
    state owns every buffer it holds, so donating rounds never invalidate
    caller-held params.  A mesh placement lays the client/pms stores out
    over the mesh's client axis.  A stateful ``compressor`` (repro.comm)
    adds the per-client error-feedback residual store ``ef``.
    ``layout`` (core.store spec, e.g. ``'virtual:host'``) swaps the dense
    stores for host-backed virtual ones."""
    return init_cohort_state(sim, strategy, x, placement, compressor,
                             layout)


def make_round_fn(sim: SimConfig, strategy: Strategy, grad_fn,
                  data: Dict[str, jax.Array], *, donate: bool = True,
                  placement=None, compressor=None, faults=None,
                  layout=None, robust=None):
    """data: per-client arrays with leading (n_clients, N_i) dims, e.g.
    {'x': (n, Ni, ...), 'y': (n, Ni)}.  Returns jitted round(state).

    ``donate=True`` donates the state pytree into the jitted call
    (``donate_argnums``) -- the client/pms stores update in place; the
    passed-in state must not be reused afterwards.  ``donate=False``
    keeps the old copying semantics, bit-for-bit.  ``placement`` picks
    where the cohort axis runs (engine.py); None = single-device vmap.
    ``compressor`` (repro.comm) compresses each client's uplink delta;
    None is trace-identical to the pre-comm engine.  ``faults``
    (repro.faults) injects + screens client faults; None (or an inactive
    config) is trace-identical to the pre-fault engine.  ``layout``
    (core.store) picks dense vs virtual client stores.  ``robust``
    (repro.robust spec/config) swaps the aggregate's mean for a robust
    reducer; None (or 'none') is trace-identical to the plain-mean
    engine."""
    return make_cohort_round(sim, strategy, grad_fn, data,
                             placement=placement, donate=donate,
                             compressor=compressor, faults=faults,
                             layout=layout, robust=robust)


def peek_sampled_clients(state, sim: SimConfig) -> jax.Array:
    """The cohort the NEXT ``round_fn(state)`` call will sample, without
    advancing the state.  Replays the engine's ``split_round_rng`` layout
    -- the split lives in exactly one function, shared with the executor
    (used by straggler accounting in benchmarks/examples).  Call BEFORE
    handing the state to a donating round_fn."""
    _, k_sel, _ = split_round_rng(state["rng"])
    return sample_cohort(k_sel, sim.n_clients, sim.m_sampled)


def peek_round_faults(state, sim: SimConfig, faults):
    """The (dropped, corrupted) lane masks the NEXT faulty round will
    draw, without advancing the state: replays ``split_round_rng`` ->
    ``fault_round_keys`` -> per-lane ``fault_draws`` -- the same three
    functions the executor traces, so the peeked schedule matches the
    executed one bitwise on every placement and block size.  Call BEFORE
    a donating round_fn."""
    from repro.faults.inject import fault_draws, fault_round_keys
    _, _, k_batch = split_round_rng(state["rng"])
    fkeys = fault_round_keys(k_batch, sim.m_sampled)
    dropped, corrupted, _ = jax.vmap(
        lambda k: fault_draws(faults, k))(fkeys)
    return dropped, corrupted


def state_is_finite(state) -> bool:
    """True iff every global-model and server-state leaf is finite -- the
    block-boundary divergence check.  Client/pms stores are deliberately
    excluded: one client's bad row cannot poison the next round's
    aggregate (screening zeroes it on upload), but a non-finite x or
    server c corrupts every subsequent round."""
    for key in ("x", "server"):
        for leaf in jax.tree.leaves(state.get(key, {})):
            if not bool(np.all(np.isfinite(np.asarray(leaf)))):
                return False
    return True


# fold_in salt for the rollback reseed: a retried block must draw a
# DIFFERENT cohort/batch schedule (retrying the exact same rng would
# deterministically re-diverge), and deriving the new key from the old
# one keeps the retry itself reproducible.
_RETRY_SALT = 0x5EED


class RollbackGuard:
    """Crash-safe recovery driver: snapshot-on-good, rollback-on-diverge.

    Holds a HOST-side copy of the last known-good state (explicit
    ``np.array(copy=True)``: donated rounds invalidate device buffers,
    and ``np.asarray`` on CPU jax arrays may alias them).  After each
    block, ``after(state)`` checks ``state_is_finite``:

      * finite -> re-snapshot, reset the retry counter, return
        ``(state, True)``;
      * non-finite -> restore the snapshot, fold a retry salt into its
        rng (the retried block draws a fresh cohort/batch/fault
        schedule), bump the retry counter, return ``(restored, False)``.
        More than ``max_retries`` CONSECUTIVE failed retries raises
        RuntimeError -- a run that diverges without faults should die
        loudly, not loop.

    ``place_state`` (a mesh placement's, optional) re-pins the restored
    snapshot to its sharded layout.  ``rollbacks`` counts total
    rollbacks for the train log."""

    def __init__(self, state, max_retries: int = 3, place_state=None):
        self.max_retries = int(max_retries)
        self.place_state = place_state
        self.retries = 0
        self.rollbacks = 0
        self._snapshot(state)

    def _snapshot(self, state) -> None:
        # virtual stores (core.store) mutate their backing tier in place
        # when a block scatters back, so the snapshot deep-clones them;
        # dense entries keep the explicit np copy
        self._good = {
            k: (v.clone() if hasattr(v, "clone")
                and hasattr(v, "gather_rows")
                else tmap(lambda t: np.array(t, copy=True), v))
            for k, v in state.items()
        }

    def _restore(self):
        # hand back CLONES of snapshotted virtual stores: the retried
        # block scatters into them, and a second rollback must still
        # find the snapshot intact
        state = {
            k: (v.clone() if hasattr(v, "clone")
                and hasattr(v, "gather_rows")
                else tmap(jnp.asarray, v))
            for k, v in self._good.items()
        }
        state["rng"] = jax.random.fold_in(
            state["rng"].astype(jnp.uint32),
            _RETRY_SALT + self.retries)
        if self.place_state is not None:
            state = self.place_state(state)
        return state

    def after(self, state):
        """``(state, ok)``: the state to continue from, and whether the
        block's result was accepted (False = rolled back)."""
        if state_is_finite(state):
            self.retries = 0
            self._snapshot(state)
            return state, True
        self.rollbacks += 1
        self.retries += 1
        if self.retries > self.max_retries:
            raise RuntimeError(
                f"RollbackGuard: global model still non-finite after "
                f"{self.max_retries} rollback retries -- divergence is "
                "not transient; check eta/faults config")
        return self._restore(), False


def run_rounds(state, round_fn, k_rounds: int, eval_fn=None,
               eval_every: int = 10, log=None):
    """Drive K rounds; returns (state, history list of metric dicts)."""
    history = []
    for k in range(k_rounds):
        state, metrics = round_fn(state)
        rec = {"round": k + 1,
               **{key: float(v) for key, v in metrics.items()}}
        if eval_fn is not None and ((k + 1) % eval_every == 0
                                    or k == k_rounds - 1):
            rec.update({k2: float(v) for k2, v in eval_fn(state).items()})
        history.append(rec)
        if log is not None:
            log(rec)
    return state, history


def run_blocks(state, make_block, k_rounds: int, block_size: int,
               eval_fn=None, log=None, on_block=None,
               first_round: int = 0, guard=None):
    """Drive ``k_rounds`` in ceil(k_rounds / block_size) scan-compiled
    blocks (``engine.make_block_fn``); returns (state, history) with the
    same per-round metric records as ``run_rounds`` -- the trajectory is
    bitwise-identical, only the host-sync/eval cadence changes.

    ``make_block(size) -> block_fn`` is called once per DISTINCT block
    size: the full ``block_size`` (compiled once, reused every block) plus
    at most one tail block when ``block_size`` does not divide
    ``k_rounds``.  Eval cadence is the block boundary: ``eval_fn(state)``
    runs after each block and its scalars land on the block's last round
    record (so with ``block_size=1`` and ``eval_every=1`` this matches
    ``run_rounds`` record-for-record).  ``on_block(state, rounds_done)``
    is the checkpoint hook -- called after each block with the live state.
    ``log`` receives each per-round record, once per round, after its
    block completes.  ``first_round`` offsets the record numbering (a
    resumed run restoring at round s passes ``first_round=s``).

    ``guard`` (a ``RollbackGuard``) makes the drive crash-safe: after
    each block the global model is checked for divergence; a non-finite
    block is DISCARDED -- the guard hands back the last good state with
    a reseeded rng, a rollback record goes to ``log``, and the same
    rounds re-run (``done`` does not advance), bounded by the guard's
    retry counter."""
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    history = []
    fns = {}
    done = 0
    while done < k_rounds:
        size = min(block_size, k_rounds - done)
        if size not in fns:
            fns[size] = make_block(size)
        state, stacked = fns[size](state)
        if guard is not None:
            state, ok = guard.after(state)
            if not ok:
                if log is not None:
                    log({"round": first_round + done + size,
                         "rollback": 1.0,
                         "rollbacks": float(guard.rollbacks)})
                continue
        stacked = {k: np.asarray(v) for k, v in stacked.items()}
        recs = [{"round": first_round + done + r + 1,
                 **{k: float(v[r]) for k, v in stacked.items()}}
                for r in range(size)]
        done += size
        if eval_fn is not None:
            recs[-1].update({k: float(v)
                             for k, v in eval_fn(state).items()})
        history.extend(recs)
        if log is not None:
            for rec in recs:
                log(rec)
        if on_block is not None:
            on_block(state, done)
    return state, history


def make_global_eval(apply_loss_fn, test_data, batch: int = 512):
    """apply_loss_fn(params, batch)->(loss, metrics w/ acc).  Full-split
    eval of the global model.

    The split is reshaped to (n_batches, batch, ...) once and scanned, so
    compile time is independent of ``n_total // batch`` (the old Python-
    unrolled loop re-traced the loss once per batch).  Every held-out
    sample is scored: the trailing ``n_total % batch`` rows -- which the
    old reshape silently DROPPED -- run through one extra fixed-shape
    call on the exact tail, and the two are combined by sample-count
    weighting, so the result is the mean over the full split.  (The loss
    fn only returns per-batch means, so a padded-and-masked tail batch
    cannot be reweighted exactly from outside -- the separate tail call
    is the masking, with the count weighting as the mask.)  Splits that
    divide evenly keep the historical batch-mean-of-means bitwise."""
    n_total = jax.tree.leaves(test_data)[0].shape[0]
    if n_total == 0:
        raise ValueError("make_global_eval: empty eval split (the old "
                         "Python-loop version deferred this to a NaN at "
                         "call time)")
    b = min(batch, n_total)
    n_batches = max(1, n_total // b)
    rem = n_total - n_batches * b
    stacked = tmap(lambda t: t[:n_batches * b]
                   .reshape((n_batches, b) + t.shape[1:]), test_data)
    tail = tmap(lambda t: t[n_batches * b:], test_data) if rem else None

    @jax.jit
    def eval_x(x):
        def body(_, mb):
            loss, m = apply_loss_fn(x, mb)
            return _, (loss, m["acc"])

        _, (losses, accs) = jax.lax.scan(body, None, stacked)
        if not rem:
            return losses.mean(), accs.mean()
        tail_loss, tail_m = apply_loss_fn(x, tail)
        loss = (losses.sum() * b + tail_loss * rem) / n_total
        acc = (accs.sum() * b + tail_m["acc"] * rem) / n_total
        return loss, acc

    def eval_fn(state):
        loss, acc = eval_x(state["x"])
        return {"test_loss": loss, "test_acc": acc}

    return eval_fn


def make_personal_eval(apply_loss_fn, personal_test):
    """Per-client personal-model eval (Fig. 7).  personal_test has leading
    (n_clients, Ni) dims."""
    @jax.jit
    def eval_pms(pms, x):
        def one(pm, td):
            loss, m = apply_loss_fn(pm, td)
            return loss, m["acc"]
        pl, pa = jax.vmap(one)(pms, personal_test)

        def one_gm(td):
            loss, m = apply_loss_fn(x, td)
            return loss, m["acc"]
        gl, ga = jax.vmap(one_gm)(personal_test)
        return pl.mean(), pa.mean(), gl.mean(), ga.mean()

    def eval_fn(state):
        pl, pa, gl, ga = eval_pms(state["pms"], state["x"])
        return {"pm_loss": pl, "pm_acc": pa, "gm_local_loss": gl,
                "gm_local_acc": ga}

    return eval_fn
