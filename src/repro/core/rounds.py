"""Simulation regime: n federated clients as a vmapped leading axis.

Reproduces the paper's experiments (n=10 cross-silo / n=100 cross-device,
client sampling, non-i.i.d splits) on a single host.  The whole round --
sampling, gather, tau local steps per selected client, scatter, aggregate --
is one jitted function.

Round buffers are DONATED by default (``make_round_fn(..., donate=True)``):
the state pytree -- dominated by the ``n_clients x params`` client/
personal-model stores -- is consumed by each jitted round call and its
buffers are reused for the output state, so the scatter updates in place
instead of doubling peak memory every round.  The contract that donation
imposes on callers: a state that has been passed to ``round_fn`` is dead
(its arrays are deleted); keep using only the returned state.
``init_sim_state`` defensively copies ``x`` so the caller's own params
survive round 1.  ``donate=False`` restores the copying behaviour
bit-for-bit (tested).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.strategies import Strategy, tmap

Pytree = Any


@dataclass(frozen=True)
class SimConfig:
    n_clients: int
    m_sampled: int
    tau: int
    batch_size: int
    seed: int = 0

    @property
    def p(self) -> float:
        return self.m_sampled / self.n_clients


def broadcast_client_store(template: Pytree, n: int) -> Pytree:
    """Per-client store from a single-client template: leading n axis,
    materialized (the stores are scattered into every round).  Shared by
    the sync and async regimes.  Stateless strategies ({}) stay {}."""
    if not jax.tree.leaves(template):
        return {}
    return tmap(lambda t: jnp.broadcast_to(t, (n,) + t.shape).copy(),
                template)


def gather_client_state(clients: Pytree, idx: jax.Array) -> Pytree:
    """Rows ``idx`` of the client store; {} for stateless strategies --
    the one empty-client-state path for both regimes."""
    if not jax.tree.leaves(clients):
        return {}
    return tmap(lambda t: t[idx], clients)


@partial(jax.jit, donate_argnums=(0,))
def scatter_client_rows(store: Pytree, idx, new: Pytree) -> Pytree:
    """``store.at[idx].set(new)`` with the store DONATED, so the
    ``n_clients x params`` buffer updates in place instead of being
    copied per call (the async regime's eager delivery path)."""
    return tmap(lambda all_, nw: all_.at[idx].set(nw), store, new)


def init_sim_state(sim: SimConfig, strategy: Strategy, x: Pytree):
    """Returns the full simulation state pytree.  ``x`` is copied: the
    state owns every buffer it holds, so donating rounds never invalidate
    caller-held params."""
    x = tmap(jnp.copy, x)
    clients = broadcast_client_store(strategy.client_init(x), sim.n_clients)
    # personalized-model store (Fig. 7): last local model per client
    pms = broadcast_client_store(x, sim.n_clients)
    return {
        "x": x,
        "clients": clients,
        "pms": pms,
        "server": strategy.server_init(x),
        "rng": jax.random.PRNGKey(sim.seed),
        "round": jnp.zeros((), jnp.int32),
    }


def _personal_model(strategy: Strategy, x, cs, upload):
    if strategy.name == "feddeper":
        return cs["v"]
    if strategy.name == "scaffold":
        return tmap(jnp.add, x, upload["dv"])
    return tmap(jnp.add, x, upload)


def make_round_fn(sim: SimConfig, strategy: Strategy, grad_fn,
                  data: Dict[str, jax.Array], *, donate: bool = True):
    """data: per-client arrays with leading (n_clients, N_i) dims, e.g.
    {'x': (n, Ni, ...), 'y': (n, Ni)}.  Returns jitted round(state).

    ``donate=True`` donates the state pytree into the jitted call
    (``donate_argnums``) -- the client/pms stores update in place; the
    passed-in state must not be reused afterwards.  ``donate=False``
    keeps the old copying semantics, bit-for-bit."""
    n, m, tau, b = (sim.n_clients, sim.m_sampled, sim.tau, sim.batch_size)
    n_i = jax.tree.leaves(data)[0].shape[1]

    def round_fn(state):
        rng, k_sel, k_batch = jax.random.split(state["rng"], 3)
        idx = jax.random.choice(k_sel, n, (m,), replace=False)  # (m,)

        # gather sampled client state + their data
        cs = gather_client_state(state["clients"], idx)
        bidx = jax.random.randint(k_batch, (m, tau, b), 0, n_i)
        batches = tmap(lambda t: jax.vmap(lambda i, bi: t[i][bi])(idx, bidx),
                       data)  # (m, tau, b, ...)

        ctx = strategy.broadcast(state["x"], state["server"])

        def per_client(cs_i, batches_i):
            return strategy.local_round(state["x"], ctx, cs_i, batches_i,
                                        grad_fn)

        new_cs, uploads, metrics = jax.vmap(per_client)(cs, batches)

        # scatter per-client state back
        clients = state["clients"]
        if jax.tree.leaves(clients):
            clients = tmap(lambda all_, new: all_.at[idx].set(new),
                           clients, new_cs)
        pms_new = jax.vmap(
            lambda cs_i, up_i: _personal_model(strategy, state["x"], cs_i,
                                               up_i))(new_cs, uploads)
        pms = tmap(lambda all_, new: all_.at[idx].set(new),
                   state["pms"], pms_new)

        x, server, agg_metrics = strategy.aggregate(
            state["x"], state["server"], uploads, sim.p)
        metrics = {k: v.mean() for k, v in metrics.items()}
        metrics.update(agg_metrics)
        return {
            "x": x, "clients": clients, "pms": pms, "server": server,
            "rng": rng, "round": state["round"] + 1,
        }, metrics

    if donate:
        return jax.jit(round_fn, donate_argnums=(0,))
    return jax.jit(round_fn)


def peek_sampled_clients(state, sim: SimConfig) -> jax.Array:
    """The cohort the NEXT ``round_fn(state)`` call will sample, without
    advancing the state.  Replays make_round_fn's rng splits -- kept here
    so the split layout lives in exactly one module (used by straggler
    accounting in benchmarks/examples).  Call BEFORE handing the state to
    a donating round_fn."""
    _, k_sel, _ = jax.random.split(state["rng"], 3)
    return jax.random.choice(k_sel, sim.n_clients, (sim.m_sampled,),
                             replace=False)


def run_rounds(state, round_fn, k_rounds: int, eval_fn=None,
               eval_every: int = 10, log=None):
    """Drive K rounds; returns (state, history list of metric dicts)."""
    history = []
    for k in range(k_rounds):
        state, metrics = round_fn(state)
        rec = {"round": k + 1,
               **{key: float(v) for key, v in metrics.items()}}
        if eval_fn is not None and ((k + 1) % eval_every == 0
                                    or k == k_rounds - 1):
            rec.update({k2: float(v) for k2, v in eval_fn(state).items()})
        history.append(rec)
        if log is not None:
            log(rec)
    return state, history


def make_global_eval(apply_loss_fn, test_data, batch: int = 512):
    """apply_loss_fn(params, batch)->(loss, metrics w/ acc).  Full-split
    eval of the global model.

    The split is reshaped to (n_batches, batch, ...) once and scanned, so
    compile time is independent of ``n_total // batch`` (the old Python-
    unrolled loop re-traced the loss once per batch).  Same batches as
    before: trailing remainder dropped, whole split in one batch when
    n_total < batch."""
    n_total = jax.tree.leaves(test_data)[0].shape[0]
    if n_total == 0:
        raise ValueError("make_global_eval: empty eval split (the old "
                         "Python-loop version deferred this to a NaN at "
                         "call time)")
    b = min(batch, n_total)
    n_batches = max(1, n_total // b)
    stacked = tmap(lambda t: t[:n_batches * b]
                   .reshape((n_batches, b) + t.shape[1:]), test_data)

    @jax.jit
    def eval_x(x):
        def body(_, mb):
            loss, m = apply_loss_fn(x, mb)
            return _, (loss, m["acc"])

        _, (losses, accs) = jax.lax.scan(body, None, stacked)
        return losses.mean(), accs.mean()

    def eval_fn(state):
        loss, acc = eval_x(state["x"])
        return {"test_loss": loss, "test_acc": acc}

    return eval_fn


def make_personal_eval(apply_loss_fn, personal_test):
    """Per-client personal-model eval (Fig. 7).  personal_test has leading
    (n_clients, Ni) dims."""
    @jax.jit
    def eval_pms(pms, x):
        def one(pm, td):
            loss, m = apply_loss_fn(pm, td)
            return loss, m["acc"]
        pl, pa = jax.vmap(one)(pms, personal_test)

        def one_gm(td):
            loss, m = apply_loss_fn(x, td)
            return loss, m["acc"]
        gl, ga = jax.vmap(one_gm)(personal_test)
        return pl.mean(), pa.mean(), gl.mean(), ga.mean()

    def eval_fn(state):
        pl, pa, gl, ga = eval_pms(state["pms"], state["x"])
        return {"pm_loss": pl, "pm_acc": pa, "gm_local_loss": gl,
                "gm_local_acc": ga}

    return eval_fn
