"""Uplink-compression layer: what each client sends across the network."""
from repro.comm.compressors import (  # noqa: F401
    Compressor,
    Identity,
    Quantize,
    TopK,
    make_compressor,
    payload_bytes,
    uplink_bytes_per_round,
)
