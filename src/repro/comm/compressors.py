"""Uplink compressors: the pluggable layer between each client's delta
and ``Strategy.aggregate``.

The paper motivates FedDeper with non-iid data AND limited bandwidth;
only the shared stream (the y-delta upload) ever crosses the network --
the personal stream v stays client-side -- so the upload is the one
high-leverage compression seam.  A ``Compressor`` sits inside the
per-client round body (``engine.make_per_client``): the client computes
its dense upload, compresses it, and the *decompressed* (what-the-server-
would-reconstruct) tensor continues into the aggregate.  Decompression
therefore always happens per-client BEFORE the cohort mean, which under
the mesh placement means before the round's single cross-client psum --
the collective count is unchanged by compression (tested).

Contract (all inside jit/vmap/shard_map, so everything is traced math):

  stateful            -- True when the compressor carries per-client
                         error-feedback residuals: the engine then owns an
                         ``n_clients x upload`` store (``state['ef']``),
                         gathered/scattered with the cohort like the
                         client/pms stores, donated, sharded by
                         ``rules.sim_state_specs``, and threaded through
                         the scan-block carry.
  init_residual(tmpl) -- one client's residual (f32 zeros, upload-shaped);
                         {} for stateless compressors.
  roundtrip(upload, ef, key, corrupt=None)
                      -- (dense_upload, new_ef, metrics): the decompressed
                         upload the server reconstructs, the residual the
                         client keeps, and optional metric scalars.  The
                         error-feedback form is the classical EF-SGD one:
                         send C(upload + ef), keep (upload + ef) - C(...).
                         ``corrupt`` (repro.faults wire-corruption hook,
                         a single-buffer fn) damages the WIRE
                         representation -- the compressed codes -- after
                         the residual is computed: EF reflects what the
                         client actually sent; bit-flips are transport
                         damage the server sees.
  payload_bytes(tmpl) -- wire bytes of ONE client's compressed upload
                         (static, from shapes): the bandwidth model for
                         the async regime's upload delay and the bench's
                         ``uplink_bytes_per_round``.

``make_compressor`` parses the CLI spec: ``none`` (-> None: the engine
takes today's code path, trace-identical), ``identity`` (the same bytes
through the comm path -- the bitwise-equivalence pin), ``q8`` / ``fp8``
(per-leaf-scale quantization, int8 stochastic rounding via the single-
launch Pallas pack kernel / deterministic e4m3 cast), ``topk:R``
(keep-ratio magnitude sparsification with error feedback).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import tmap
from repro.kernels.ops import dequantize, quantize_stochastic
from repro.kernels.tiling import TreeFlattener

Pytree = Any

_F32 = jnp.float32


def _leaf_sizes(template) -> Tuple[int, int]:
    """(total elements, leaf count) of an upload template (arrays or
    ShapeDtypeStructs)."""
    leaves = jax.tree.leaves(template)
    return sum(int(np.prod(l.shape, dtype=np.int64)) for l in leaves), \
        len(leaves)


def _dense_bytes(template) -> int:
    return sum(int(np.prod(l.shape, dtype=np.int64)) *
               jnp.dtype(l.dtype).itemsize for l in jax.tree.leaves(template))


def _to_f32(tree: Pytree) -> Pytree:
    return tmap(lambda t: t.astype(_F32), tree)


def _like(tree: Pytree, ref: Pytree) -> Pytree:
    """Cast ``tree`` back to ``ref``'s leaf dtypes (the upload dtype the
    aggregate has always seen)."""
    return tmap(lambda t, r: t.astype(r.dtype), tree, ref)


@dataclass(frozen=True)
class Compressor:
    """Base = identity: the upload crosses the wire untouched."""

    name = "identity"
    stateful = False

    def init_residual(self, template: Pytree) -> Pytree:
        return {}

    def roundtrip(self, upload: Pytree, ef: Pytree, key, corrupt=None
                  ) -> Tuple[Pytree, Pytree, Dict]:
        if corrupt is not None:
            # dense wire: the payload itself is the wire buffer, per leaf
            upload = tmap(corrupt, upload)
        return upload, ef, {}

    def payload_bytes(self, template: Pytree) -> int:
        return _dense_bytes(template)


class Identity(Compressor):
    """Explicit pass-through: exercises the comm path (extra ef/key
    plumbing traced and DCE'd) while producing bitwise the no-compressor
    trajectory -- the equivalence pin for the whole layer."""


@dataclass(frozen=True)
class Quantize(Compressor):
    """Per-leaf-scale quantization of the whole upload tree.

    Each leaf is normalized by its own ``amax / qmax`` scale, the
    normalized tree is packed into ONE ``(rows, LANES)`` buffer
    (``TreeFlattener`` -- the PR 2 packing), and

      * ``mode='int8'``: stochastically rounded to int8 in a single
        Pallas launch (``kernels/quantize.py``); unbiased, so no error
        feedback is needed;
      * ``mode='fp8'``: cast to float8_e4m3fn (nearest; e4m3 carries its
        own mantissa so per-element stochastic bits buy little) -- the
        scale maps amax onto the e4m3 max (448) so no finite input can
        overflow to inf/nan (tested).

    Wire format: the packed low-precision buffer + one f32 scale per
    leaf.  ``payload_bytes`` counts exactly that."""

    mode: str = "int8"  # 'int8' | 'fp8'

    def __post_init__(self):
        if self.mode not in ("int8", "fp8"):
            raise ValueError(f"Quantize mode {self.mode!r} "
                             "(want 'int8' | 'fp8')")

    @property
    def name(self) -> str:  # type: ignore[override]
        return "q8" if self.mode == "int8" else "fp8"

    @property
    def qmax(self) -> float:
        return 127.0 if self.mode == "int8" else 448.0  # e4m3fn max

    def _scales(self, tree_f32: Pytree) -> Pytree:
        return tmap(lambda t: jnp.maximum(jnp.max(jnp.abs(t)),
                                          1e-30) / self.qmax, tree_f32)

    def roundtrip(self, upload, ef, key, corrupt=None):
        up = _to_f32(upload)
        scales = self._scales(up)
        normed = tmap(jnp.divide, up, scales)
        # same flattener policy as ops.deper_update: one whole-buffer
        # block off-TPU (interpret bypass), padded row-block multiples on
        # TPU so awkward row counts can't degrade the pack kernel's grid
        from repro.kernels.ops import _interpret
        from repro.kernels.quantize import DEFAULT_BLOCK_ROWS
        block = None if _interpret() else DEFAULT_BLOCK_ROWS
        fl = TreeFlattener(up, block_rows=block)
        buf = fl.flatten(normed)
        if self.mode == "int8":
            rand = jax.random.uniform(key, buf.shape, _F32)
            q = quantize_stochastic(buf, rand)
            if corrupt is not None:
                # bit-flips hit the int8 WIRE codes: bounded damage
                # (|value| <= scale * 127), the realistic transport model
                q = corrupt(q)
            deq_buf = dequantize(q)
        else:
            deq_buf = buf.astype(jnp.float8_e4m3fn).astype(_F32)
            if corrupt is not None:
                # fp8 wire: flip on the decoded f32 buffer (bitcast of
                # float8 is version-fragile on jax 0.4.x)
                deq_buf = corrupt(deq_buf)
        dense = tmap(jnp.multiply, fl.unflatten(deq_buf), scales)
        return _like(dense, upload), ef, {}

    def payload_bytes(self, template) -> int:
        size, n_leaves = _leaf_sizes(template)
        return size * 1 + n_leaves * 4  # 1 byte/elem + f32 scale per leaf


@dataclass(frozen=True)
class TopK(Compressor):
    """Magnitude sparsification with client-side error feedback.

    Keep the ``ratio`` fraction of largest-magnitude elements of EACH
    leaf (per-tensor budget ``k_i = round(ratio * size_i)``, the DGC /
    layer-wise convention).  A single global budget over the packed tree
    was measured and rejected: on the reduced-llama LM the tied
    embedding/lm_head leaf -- whose softmax gradient spreads over the
    vocab, giving small per-ELEMENT magnitudes but all of the
    next-token-accuracy signal -- won only 1.5% of its elements while
    dense FFN/attention leaves took 20-60%, and eval accuracy cratered
    until error feedback slowly drained the starved rows (DESIGN.md §8).
    Per-leaf budgets guarantee every layer its share of the wire.

    Biased, so the dropped mass is carried in the client's residual and
    re-added next time it is sampled (EF-SGD): send C(upload + ef), keep
    (upload + ef) - C(upload + ef).

    Edge cases pinned by tests: ``ratio=0`` -> k=0 everywhere -> the
    upload is all zeros and the entire corrected delta lands in the
    residual; ``ratio=1`` -> k=all -> exact pass-through of upload + ef
    with a zero residual.

    Wire format: per leaf, k_i (value, flat-index) pairs -> 8 bytes
    each."""

    ratio: float = 0.1

    stateful = True

    def __post_init__(self):
        if not 0.0 <= self.ratio <= 1.0:
            raise ValueError(f"TopK ratio must be in [0, 1], "
                             f"got {self.ratio}")

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"topk:{self.ratio:g}"

    def k_for(self, size: int) -> int:
        return min(size, int(round(self.ratio * size)))

    def init_residual(self, template):
        return tmap(lambda t: jnp.zeros(t.shape, _F32), template)

    def _sparsify_leaf(self, leaf):
        """Per-leaf ``lax.top_k`` reference implementation: kept as the
        bitwise oracle for ``_sparsify_packed`` (tested equal, ties
        included); the hot path no longer calls it."""
        flat = leaf.reshape(-1)
        k = self.k_for(flat.shape[0])
        if k == 0:
            return jnp.zeros_like(leaf)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(
            leaf.shape)

    def _sparsify_packed(self, corrected):
        """Every leaf's exact top-k mask in ONE threshold pass over the
        packed ``TreeFlattener`` buffer, replacing a full ``lax.top_k``
        sort per leaf (the ``speedup_vs_dense 0.35`` hot spot; ~5x
        faster on the MLP upload tree, measured on CPU).

        The per-leaf k_i-th-largest-magnitude threshold is found by a
        31-step bisection on the int32 bit patterns of the non-negative
        magnitudes -- the IEEE ordering of non-negative f32 is monotone
        in its bit pattern, so the bisection is EXACT, not approximate.
        Each step compares every leaf's contiguous slice of the packed
        buffer against a scalar candidate and reduces: no sorts, no
        gathers, no scatters.  Elements strictly above the threshold
        are kept; ties AT the threshold are kept lowest-flat-index-first
        via a running count, reproducing ``lax.top_k``'s stable
        tie-break -- the kept set, and hence the dense output, is
        bitwise equal to the per-leaf reference (tested, ties
        included)."""
        leaves = jax.tree.leaves(corrected)
        sizes = [int(np.prod(l.shape, dtype=np.int64)) for l in leaves]
        ks = [self.k_for(s) for s in sizes]
        if not any(ks):
            return tmap(jnp.zeros_like, corrected)
        if all(k == s for k, s in zip(ks, sizes)):
            return corrected
        from repro.kernels.ops import _interpret
        from repro.kernels.quantize import DEFAULT_BLOCK_ROWS
        block = None if _interpret() else DEFAULT_BLOCK_ROWS
        fl = TreeFlattener(corrected, block_rows=block)
        buf = fl.flatten(corrected)
        flat = buf.reshape(-1)
        abits = jax.lax.bitcast_convert_type(jnp.abs(flat), jnp.int32)
        slices = [abits[o:o + s]
                  for o, s in zip(fl.offsets, fl.sizes)]
        k_vec = jnp.asarray(np.array(ks, np.int32))

        def step(carry, _):
            lo, hi = carry
            mid = lo + (hi - lo + 1) // 2
            cnt = jnp.stack([
                jnp.sum((sl >= mid[i]).astype(jnp.int32))
                for i, sl in enumerate(slices)])
            ge = cnt >= k_vec
            return (jnp.where(ge, mid, lo),
                    jnp.where(ge, hi, mid - 1)), None

        lo0 = jnp.zeros(len(slices), jnp.int32)
        # hi starts at the +inf bit pattern: the full non-negative f32
        # range, halved to one exact bit pattern in 31 steps
        hi0 = jnp.full(len(slices), np.int32(0x7F800000))
        (thr, _), _ = jax.lax.scan(step, (lo0, hi0), None, length=31)
        parts = []
        for i, (sl, o, s) in enumerate(zip(slices, fl.offsets,
                                           fl.sizes)):
            gt = sl > thr[i]
            eq = sl == thr[i]
            cnt_gt = jnp.sum(gt.astype(jnp.int32))
            rank = jnp.cumsum(eq.astype(jnp.int32)) - 1
            keep = gt | (eq & (rank < (k_vec[i] - cnt_gt)))
            parts.append(jnp.where(keep, flat[o:o + s], 0.0))
        if fl.padded > fl.size:
            parts.append(jnp.zeros(fl.padded - fl.size, jnp.float32))
        return fl.unflatten(jnp.concatenate(parts).reshape(buf.shape))

    def roundtrip(self, upload, ef, key, corrupt=None):
        corrected = tmap(jnp.add, _to_f32(upload), ef)
        dense = self._sparsify_packed(corrected)
        new_ef = tmap(jnp.subtract, corrected, dense)
        if corrupt is not None:
            # transport damage AFTER the residual: EF keeps reflecting
            # what the client sent, not what the wire mangled
            dense = tmap(corrupt, dense)
        res = sum(jnp.sum(jnp.square(l))
                  for l in jax.tree.leaves(new_ef))
        return (_like(dense, upload), new_ef,
                {"ef_norm": jnp.sqrt(res)})

    def payload_bytes(self, template) -> int:
        return sum(
            self.k_for(int(np.prod(l.shape, dtype=np.int64))) * (4 + 4)
            for l in jax.tree.leaves(template))


def make_compressor(spec: Optional[str]) -> Optional[Compressor]:
    """CLI spec -> compressor.  ``None``/``'none'``/``''`` -> None (the
    engine's no-comm path, trace-identical to the pre-comm engine);
    ``identity`` | ``q8`` | ``fp8`` | ``topk:R`` (R = keep ratio in
    [0, 1], e.g. ``topk:0.1``).  Lexing/errors via the shared
    ``configs.specs.parse_spec`` mini-language helper."""
    if spec is None or spec in ("", "none"):
        return None
    from repro.configs.specs import cast_value, parse_spec
    p = parse_spec(spec, flag="--compress",
                   heads=("none", "identity", "q8", "fp8", "topk"),
                   arity={"topk": (1, 1)}, head_label="compressor")
    if p.head == "none":
        return None
    if p.head == "identity":
        return Identity()
    if p.head == "q8":
        return Quantize("int8")
    if p.head == "fp8":
        return Quantize("fp8")
    ratio = cast_value("--compress", "topk ratio", p.args[0], float)
    return TopK(ratio)


def payload_bytes(compressor: Optional[Compressor], template: Pytree) -> int:
    """Wire bytes of one client's upload under ``compressor`` (None =
    dense)."""
    return (compressor or Compressor()).payload_bytes(template)


def uplink_bytes_per_round(compressor: Optional[Compressor],
                           strategy, x: Pytree, m_sampled: int) -> int:
    """Total uplink bytes one synchronous round moves: ``m_sampled``
    clients each ship one compressed upload (shape from
    ``strategy.upload_template``)."""
    return payload_bytes(compressor, strategy.upload_template(x)) * m_sampled
