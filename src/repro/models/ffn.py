"""Dense feed-forward: gated (SwiGLU/GeGLU) or plain MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init


def init_ffn(cfg, rng, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {
        "w_up": dense_init(ks[0], d, f, dtype),
        "w_down": dense_init(ks[1], f, d, dtype),
    }
    if cfg.gated_ffn:
        p["w_gate"] = dense_init(ks[2], d, f, dtype)
    return p


def apply_ffn(cfg, params, x):
    act = activation(cfg.act)
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = act(x @ params["w_gate"]) * up
    else:
        up = act(up)
    return up @ params["w_down"]
