"""The paper's own ML models: MLP and CNN classifiers (pure JAX).

Used by the simulation regime to reproduce Figs. 1, 3-7 and Table 1 on
synthetic non-i.i.d splits.  CNNs follow the paper's architecture section:
conv stacks (3x3 or 5x5, stride 1, same padding, 2x2 maxpool after each)
followed by fully-connected layers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import cross_entropy, dense_init


def init_classifier(cfg, rng, dtype=jnp.float32):
    ks = iter(jax.random.split(rng, 16))
    params = {}
    if cfg.kind == "cnn":
        h, w, c_in = cfg.input_shape
        convs = []
        for c_out in cfg.conv_channels:
            k = cfg.kernel_size
            w_conv = dense_init(next(ks), k * k * c_in, c_out, dtype,
                                shape=(k, k, c_in, c_out))
            convs.append({"w": w_conv, "b": jnp.zeros((c_out,), dtype)})
            c_in = c_out
            h, w = h // 2, w // 2  # 2x2 maxpool
        params["convs"] = convs
        flat = h * w * c_in
    else:
        (flat,) = cfg.input_shape
    dims = [flat, *cfg.hidden, cfg.num_classes]
    params["dense"] = [
        {"w": dense_init(next(ks), i, o, dtype), "b": jnp.zeros((o,), dtype)}
        for i, o in zip(dims[:-1], dims[1:])
    ]
    return params


def apply_classifier(cfg, params, x):
    """x: (B, *input_shape) -> logits (B, num_classes)."""
    B = x.shape[0]
    if cfg.kind == "cnn":
        x = x.reshape(B, *cfg.input_shape)
        for conv in params["convs"]:
            x = jax.lax.conv_general_dilated(
                x, conv["w"], window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + conv["b"]
            x = jax.nn.relu(x)
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
    x = x.reshape(B, -1)
    for i, layer in enumerate(params["dense"]):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params["dense"]):
            x = jax.nn.relu(x)
    return x


def classifier_loss(cfg, params, batch):
    """batch: x (B, ...), y (B,) int.  Returns (loss, metrics)."""
    logits = apply_classifier(cfg, params, batch["x"])
    loss = cross_entropy(logits, batch["y"])
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}
