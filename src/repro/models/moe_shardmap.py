"""Expert-parallel MoE with explicit all-to-all (shard_map formulation).

GSPMD lowers the global capacity-dispatch scatter-add into a full-buffer
all-reduce (~E*cap*d bytes per layer -- measured 84 GB/layer/device for
deepseek-v3 prefill).  The canonical TPU MoE instead exchanges exactly the
routed tokens twice with all-to-alls over the expert-parallel axis:

  per data shard: route local tokens -> local (E, cap_loc, d) buffer
  all_to_all over 'model': (E, cap_loc, d) -> (E_loc, 16*cap_loc, d)
  local expert FFN (E_loc experts)
  all_to_all back -> local combine

Payload per direction = one copy of the routed tokens (k*T*d*(n-1)/n),
independent of expert count.  Capacity is per-data-shard (cap_loc =
cap/data_size), which is the standard formulation and *more* drop-robust
under skew than a global queue.  Falls back to the pjit version when no
mesh context / axes are unavailable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.models.common import activation
from repro.models.moe import MoEAux, _capacity


def _local_dispatch(cfg, xt, router_w):
    """Route local tokens.  xt: (T_loc, d).  Returns buffers + combine
    metadata, all shard-local."""
    E, k = cfg.num_experts, cfg.experts_per_token
    T_loc, d = xt.shape
    logits = (xt @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)
    n_assign = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    ranks = jnp.arange(n_assign, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((n_assign,), jnp.int32).at[order].set(ranks)
    return logits, probs, gates, eidx, flat_e, pos


def apply_moe_shardmap(cfg, params, x, *, data_axes=("data",),
                       model_axis="model", mesh=None):
    """Drop-in for apply_moe under a mesh with data/model axes.

    x: (B, S, d); expert weights sharded E-over-model (divisibility
    required)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    act = activation(cfg.act)

    # tokens shard over EVERY non-model axis AND the model axis: each
    # device routes a disjoint token slice, so the all_to_all merges
    # disjoint slot sets (no duplicated expert compute).
    tok_axes = tuple(data_axes) + (model_axis,)

    def body(xt, router_w, wg, wu, wd):
        # xt: (T_loc, d); wg/wu: (E_loc, d, f); wd: (E_loc, f, d)
        T_loc = xt.shape[0]
        n_model = axis_size(model_axis)
        E_loc = wg.shape[0]
        cap = _capacity(cfg, T_loc)  # per-token-shard capacity
        logits, probs, gates, eidx, flat_e, pos = _local_dispatch(
            cfg, xt, router_w)
        keep = pos < cap
        safe_pos = jnp.where(keep, pos, cap - 1)
        tok_id = jnp.repeat(jnp.arange(T_loc), k)
        buf = jnp.zeros((E, cap, d), xt.dtype)
        contrib = jnp.where(keep[:, None], xt[tok_id], 0)
        buf = buf.at[flat_e, safe_pos].add(contrib)

        # exchange: every model shard gets its E_loc experts' slots from
        # every peer: (n_model, E_loc, cap, d) -a2a-> (E_loc, n*cap, d)
        buf = buf.reshape(n_model, E_loc, cap, d)
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                 concat_axis=1)
        buf = buf.reshape(E_loc, n_model * cap, d)

        h = act(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd)

        # return trip: (E_loc, n*cap, d) -> (E, cap, d)
        y = y.reshape(E_loc, n_model, cap, d)
        y = jax.lax.all_to_all(y, model_axis, split_axis=1, concat_axis=0)
        y = y.reshape(E, cap, d)

        picked = y[flat_e, safe_pos]
        w = (gates.reshape(-1) * keep).astype(xt.dtype)
        out = jnp.zeros((T_loc, d), xt.dtype).at[tok_id].add(
            picked * w[:, None])

        me = probs.mean(0)
        ce = jax.nn.one_hot(eidx, E).sum(1).mean(0) / k
        lb = E * jnp.sum(me * ce)
        rz = jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)
        dropped = 1.0 - keep.mean()
        stats = jnp.stack([lb, rz, dropped])
        for a in tok_axes:
            stats = jax.lax.pmean(stats, a)
        return out, stats

    sm = shard_map(
        body, mesh=mesh,
        in_specs=(P(tok_axes, None), P(), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=(P(tok_axes, None), P()),
    )
    xt = x.reshape(B * S, d)
    out, stats = sm(xt, params["router"], params["we_gate"],
                    params["we_up"], params["we_down"])
    out = out.reshape(B, S, d)

    if cfg.num_shared_experts:
        sp = params["shared"]
        xt2 = x
        h = act(xt2 @ sp["w_gate"]) * (xt2 @ sp["w_up"])
        out = out + h @ sp["w_down"]

    return out, MoEAux(stats[0], stats[1], stats[2])
