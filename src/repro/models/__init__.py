"""Model zoo: sequence models per ArchConfig + the paper's classifiers."""
from repro.models.transformer import (  # noqa: F401
    active_param_count,
    decode_step,
    init_cache,
    init_model,
    loss_fn,
    param_count,
    param_shapes,
    prefill,
)
from repro.models.classifier import (  # noqa: F401
    apply_classifier,
    classifier_loss,
    init_classifier,
)
