"""Attention: GQA (+bias/sliding-window/softcap) and MLA (DeepSeek-V3).

Three execution modes share one set of weights:

* ``train``   -- full-sequence causal, no cache, chunked online-softmax
  (lax.scan over KV blocks) so the S^2 score matrix is never materialized;
  this is the pure-jnp analogue of the Pallas flash kernel.
* ``prefill`` -- same math, additionally returns the populated KV cache.
* ``decode``  -- one new token against the cache; sliding-window layers use
  a ring buffer of ``window`` slots (slot = position mod window).

MLA caches the compressed latent (kv_lora + rope dims) and decodes in the
*absorbed* form (queries projected into latent space), which is the
TPU-native adaptation: tiny cache, MXU-heavy score computation.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.models.common import apply_rope, dense_init, init_rmsnorm, rmsnorm, softcap

NEG_INF = -2.0 ** 30  # large-but-finite; avoids NaNs in fully-masked rows


def _pick_chunk(s: int, target: int = 512) -> int:
    if s % target == 0:
        return target
    for c in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if s % c == 0 and c <= s:
            return c
    return s


# ---------------------------------------------------------------------------
# core chunked attention (shared by GQA and expanded MLA)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, q_positions, k_positions, causal=True,
                      window: Optional[int] = None,
                      cap: Optional[float] = None,
                      q_chunk: int = 512, kv_chunk: int = 1024):
    """q: (B,Sq,H,Dq)  k: (B,Sk,K,Dq)  v: (B,Sk,K,Dv), H = K*G.

    Online-softmax over KV chunks; lax.map over Q chunks.  Positions are
    global token indices used for causal / sliding-window masks.
    """
    B, Sq, H, Dq = q.shape
    _, Sk, K, Dv = v.shape
    G = H // K
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Sk, kv_chunk)
    nq, nk = Sq // qc, Sk // kc
    scale = Dq ** -0.5

    qs = q.reshape(B, nq, qc, K, G, Dq).transpose(1, 0, 2, 3, 4, 5)
    qp = q_positions.reshape(nq, qc)
    ks = k.reshape(B, nk, kc, K, Dq).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kc, K, Dv).transpose(1, 0, 2, 3, 4)
    kp = k_positions.reshape(nk, kc)

    def one_q_chunk(args):
        qb, qpos = args  # (B,qc,K,G,Dq), (qc,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, kpos = inp
            s = jnp.einsum("bqkgd,bjkd->bkgqj", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            s = softcap(s, cap)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask[None, None, None]
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqj,bjkd->bkgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qc), jnp.float32)
        a0 = jnp.zeros((B, K, G, qc, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(B, qc, H, Dv)

    out = jax.lax.map(one_q_chunk, (qs, qp))  # (nq,B,qc,H,Dv)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def decode_attention_seq_sharded(q, k_cache, v_cache, *, valid_len,
                                 cap: Optional[float] = None,
                                 axis: str = "model"):
    """Sequence-parallel flash-decode: the KV cache is sharded over its
    length dim on mesh axis ``axis``; each shard computes a local online
    softmax over its slots and the shards combine with tiny collectives
    (max + sum of (B,H)-sized stats and one (B,H,Dv) partial output)
    instead of letting GSPMD reshard the whole cache per layer.

    Must be called under shard_map with q/valid_len replicated over
    ``axis`` and caches length-sharded; returns replicated output."""
    B, _, H, Dq = q.shape
    _, L_loc, K, Dv = v_cache.shape
    G = H // K
    scale = Dq ** -0.5
    shard = jax.lax.axis_index(axis)
    offset = shard * L_loc
    qh = q.reshape(B, K, G, Dq).astype(jnp.float32)
    s = jnp.einsum("bkgd,bjkd->bkgj", qh,
                   k_cache.astype(jnp.float32)) * scale
    s = softcap(s, cap)
    mask = (offset + jnp.arange(L_loc))[None, None, None, :] < valid_len
    s = jnp.where(mask, s, NEG_INF)
    m_loc = jnp.max(s, axis=-1)                      # (B,K,G)
    m_glob = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(s - m_glob[..., None]) * mask
    l_loc = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    l_glob = jax.lax.psum(l_loc, axis)
    acc = jax.lax.psum(acc, axis)
    out = acc / jnp.maximum(l_glob, 1e-30)[..., None]
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, valid_len,
                     cap: Optional[float] = None):
    """q: (B,1,H,Dq), caches (B,L,K,D*); ``valid_len`` = #valid slots --
    a scalar, or a (B,) vector of per-row live lengths (mixed batch)."""
    B, _, H, Dq = q.shape
    _, L, K, Dv = v_cache.shape
    G = H // K
    scale = Dq ** -0.5
    qh = q.reshape(B, K, G, Dq).astype(jnp.float32)
    s = jnp.einsum("bkgd,bjkd->bkgj", qh, k_cache.astype(jnp.float32)) * scale
    s = softcap(s, cap)
    valid = jnp.asarray(valid_len)
    if valid.ndim == 1:
        valid = valid.reshape(-1, 1, 1, 1)
    mask = jnp.arange(L)[None, None, None, :] < valid
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (B, L, K, D)
    v: jax.Array  # (B, L, K, D)


def init_gqa(cfg, rng, dtype, *, cross=False):
    d, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d, H * Dh, dtype),
        "wk": dense_init(ks[1], d, K * Dh, dtype),
        "wv": dense_init(ks[2], d, K * Dh, dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((K * Dh,), dtype)
        p["bv"] = jnp.zeros((K * Dh,), dtype)
    return p


def _proj_qkv(cfg, params, xq, xkv, *, rope_q_pos=None, rope_k_pos=None):
    B, Sq, _ = xq.shape
    Sk = xkv.shape[1]
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = xq @ params["wq"]
    k = xkv @ params["wk"]
    v = xkv @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, Sq, H, Dh)
    k = k.reshape(B, Sk, K, Dh)
    v = v.reshape(B, Sk, K, Dh)
    if rope_q_pos is not None:
        q = apply_rope(q, rope_q_pos, cfg.rope_theta)
        k = apply_rope(k, rope_k_pos, cfg.rope_theta)
    return q, k, v


def _shard_map_decode(q, kc, vc, k_new, v_new, pos, *, cap, seq_shard):
    """Seq-parallel flash-decode under shard_map, *including* the cache
    update: the owner shard of slot ``pos`` does a local
    dynamic-update-slice -- a boundary-crossing DUS on the sharded length
    dim otherwise costs a full-cache collective per layer (measured
    ~4 GB/layer on qwen2 decode).

    seq_shard = {"axis": model axis, "dp": batch axes, "mesh": mesh}.
    Returns (out, new_k_cache, new_v_cache)."""
    from jax.sharding import PartitionSpec as P
    axis = seq_shard["axis"]
    dp = tuple(seq_shard.get("dp", ()) or ())
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    qspec = P(bspec, None, None, None)
    cspec = P(bspec, axis, None, None)

    def body(q_, k_, v_, kn, vn, p):
        L_loc = k_.shape[1]
        shard = jax.lax.axis_index(axis)
        owner = (p // L_loc) == shard
        local_slot = p % L_loc
        k_upd = jax.lax.dynamic_update_slice_in_dim(
            k_, kn.astype(k_.dtype), local_slot, axis=1)
        v_upd = jax.lax.dynamic_update_slice_in_dim(
            v_, vn.astype(v_.dtype), local_slot, axis=1)
        k_ = jnp.where(owner, k_upd, k_)
        v_ = jnp.where(owner, v_upd, v_)
        out = decode_attention_seq_sharded(q_, k_, v_, valid_len=p + 1,
                                           cap=cap, axis=axis)
        return out, k_, v_

    return shard_map(body, mesh=seq_shard.get("mesh"),
                         in_specs=(qspec, cspec, cspec, qspec, qspec, P()),
                         out_specs=(qspec, cspec, cspec))(
        q, kc, vc, k_new, v_new, pos)


def gqa_cache_spec(cfg, spec, batch: int, max_len: int, dtype):
    K, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    L = min(spec.window, max_len) if spec.window else max_len
    shape = (batch, L, K, Dh)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def apply_gqa(cfg, spec, params, x, *, positions, mode, cache=None, pos=None,
              causal=True, seq_shard=None, use_pallas=False):
    """Self-attention.  Returns (out, new_cache)."""
    B, S, _ = x.shape
    if mode in ("train", "prefill"):
        q, k, v = _proj_qkv(cfg, params, x, x,
                            rope_q_pos=positions, rope_k_pos=positions)
        out = chunked_attention(q, k, v, q_positions=positions[0],
                                k_positions=positions[0], causal=causal,
                                window=spec.window, cap=cfg.attn_softcap)
        new_cache = None
        if mode == "prefill":
            L = cache.k.shape[1]
            if spec.window and S >= L:
                ks = jnp.roll(k[:, S - L:], S % L, axis=1)
                vs = jnp.roll(v[:, S - L:], S % L, axis=1)
                new_cache = KVCache(ks.astype(cache.k.dtype),
                                    vs.astype(cache.v.dtype))
            else:
                new_cache = KVCache(
                    jax.lax.dynamic_update_slice_in_dim(
                        cache.k, k.astype(cache.k.dtype), 0, axis=1),
                    jax.lax.dynamic_update_slice_in_dim(
                        cache.v, v.astype(cache.v.dtype), 0, axis=1))
        return x_out(cfg, params, out, B, S), new_cache

    # decode: one token at global position ``pos`` -- a scalar int32, or
    # a (B,) vector of per-row positions (the serve engine's slot batch)
    q, k, v = _proj_qkv(cfg, params, x, x,
                        rope_q_pos=positions, rope_k_pos=positions)
    L = cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    slot = pos % L if spec.window else pos
    if seq_shard is not None and not spec.window:
        # cache update happens inside the shard_map (owner-local DUS);
        # window (ring) layers keep the dense path -- their caches are
        # small (window slots) and stay unsharded in length
        out, kc, vc = _shard_map_decode(q, cache.k, cache.v, k, v, pos,
                                        cap=cfg.attn_softcap,
                                        seq_shard=seq_shard)
        # pin the scan-carry layout so the per-layer cache doesn't get
        # resharded between the carry and the shard_map boundary
        from jax.sharding import PartitionSpec as P
        dp = tuple(seq_shard.get("dp", ()) or ())
        bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
        cspec = P(bspec, seq_shard["axis"], None, None)
        kc = jax.lax.with_sharding_constraint(kc, cspec)
        vc = jax.lax.with_sharding_constraint(vc, cspec)
        return x_out(cfg, params, out, B, 1), KVCache(kc, vc)
    if pos.ndim == 1:
        dus = jax.vmap(functools.partial(
            jax.lax.dynamic_update_slice_in_dim, axis=0))
        kc = dus(cache.k, k.astype(cache.k.dtype), slot)
        vc = dus(cache.v, v.astype(cache.v.dtype), slot)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), slot, axis=1)
    valid = jnp.minimum(pos + 1, L)
    if use_pallas:
        from repro.kernels.ops import flash_decode
        out = flash_decode(q, kc, vc, lens=valid, cap=cfg.attn_softcap)
    else:
        out = decode_attention(q, kc, vc, valid_len=valid,
                               cap=cfg.attn_softcap)
    return x_out(cfg, params, out, B, 1), KVCache(kc, vc)


def apply_cross_attention(cfg, params, x, memory, *, mem_cache=None):
    """Encoder-decoder cross attention (no causal mask, no rope).

    ``mem_cache``: optional precomputed (k, v) from ``memory`` (decode path).
    """
    B, S, _ = x.shape
    if mem_cache is None:
        q, k, v = _proj_qkv(cfg, params, x, memory)
    else:
        H, Dh = cfg.num_heads, cfg.resolved_head_dim
        q = (x @ params["wq"]).reshape(B, S, H, Dh)
        k, v = mem_cache
    M = k.shape[1]
    if S == 1:
        out = decode_attention(q, k, v, valid_len=M)
    else:
        qpos = jnp.arange(S)
        kpos = jnp.arange(M)
        out = chunked_attention(q, k, v, q_positions=qpos, k_positions=kpos,
                                causal=False, window=None)
    return x_out(cfg, params, out, B, S), (k, v)


def x_out(cfg, params, out, B, S):
    return out.reshape(B, S, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    ckv: jax.Array    # (B, L, r)      compressed latent
    krope: jax.Array  # (B, L, dr)     shared rope key


def init_mla(cfg, rng, dtype):
    d, H = cfg.d_model, cfg.num_heads
    r, rq = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 8)
    return {
        "wdq": dense_init(ks[0], d, rq, dtype),
        "q_norm": init_rmsnorm(rq, dtype),
        "wuq": dense_init(ks[1], rq, H * (dn + dr), dtype),
        "wdkv": dense_init(ks[2], d, r + dr, dtype),
        "kv_norm": init_rmsnorm(r, dtype),
        "wuk": dense_init(ks[3], r, H * dn, dtype),
        "wuv": dense_init(ks[4], r, H * dv, dtype),
        "wo": dense_init(ks[5], H * dv, d, dtype),
    }


def _mla_q(cfg, params, x, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    ql = rmsnorm(params["q_norm"], x @ params["wdq"], cfg.norm_eps)
    q = (ql @ params["wuq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, params, x, positions):
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    dkv = x @ params["wdkv"]
    ckv = rmsnorm(params["kv_norm"], dkv[..., :r], cfg.norm_eps)
    krope = apply_rope(dkv[..., r:][:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]
    return ckv, krope


def mla_cache_spec(cfg, batch: int, max_len: int, dtype):
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    return MLACache(ckv=jnp.zeros((batch, max_len, r), dtype),
                    krope=jnp.zeros((batch, max_len, dr), dtype))


def apply_mla(cfg, spec, params, x, *, positions, mode, cache=None, pos=None,
              seq_shard=None, use_pallas=False):
    B, S, _ = x.shape
    H = cfg.num_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)

    if mode in ("train", "prefill"):
        # expanded form: amortize latent up-projection over all queries
        q_nope, q_rope = _mla_q(cfg, params, x, positions)
        ckv, krope = _mla_latent(cfg, params, x, positions)
        k_nope = (ckv @ params["wuk"]).reshape(B, S, H, dn)
        v = (ckv @ params["wuv"]).reshape(B, S, H, dv)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, dr))],
            axis=-1)
        out = chunked_attention(q, k, v, q_positions=positions[0],
                                k_positions=positions[0], causal=True,
                                window=spec.window, cap=cfg.attn_softcap)
        new_cache = None
        if mode == "prefill":
            new_cache = MLACache(
                jax.lax.dynamic_update_slice_in_dim(
                    cache.ckv, ckv.astype(cache.ckv.dtype), 0, axis=1),
                jax.lax.dynamic_update_slice_in_dim(
                    cache.krope, krope.astype(cache.krope.dtype), 0, axis=1))
        return x_out(cfg, params, out, B, S), new_cache

    # decode: absorbed form, scores computed in latent space.  ``pos`` is
    # a scalar int32 or a (B,) vector of per-row positions.
    q_nope, q_rope = _mla_q(cfg, params, x, positions)  # (B,1,H,dn),(B,1,H,dr)
    ckv_t, krope_t = _mla_latent(cfg, params, x, positions)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        dus = jax.vmap(functools.partial(
            jax.lax.dynamic_update_slice_in_dim, axis=0))
        ckv = dus(cache.ckv, ckv_t.astype(cache.ckv.dtype), pos)
        krope = dus(cache.krope, krope_t.astype(cache.krope.dtype), pos)
    else:
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache.ckv, ckv_t.astype(cache.ckv.dtype), pos, axis=1)
        krope = jax.lax.dynamic_update_slice_in_dim(
            cache.krope, krope_t.astype(cache.krope.dtype), pos, axis=1)
    wuk = params["wuk"].reshape(r, H, dn)
    # absorb W_uk into the query:  q_lat[h] = q_nope[h] @ W_uk[:,h,:]^T
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    if seq_shard is not None:
        o_lat = _mla_shard_map_decode(q_lat, q_rope, ckv, krope, pos + 1,
                                      scale=scale, cap=cfg.attn_softcap,
                                      seq_shard=seq_shard)
    elif use_pallas:
        # latent decode IS a GQA decode with one kv head: scores are
        # [q_lat | q_rope] . [ckv | krope], values are ckv -- so the
        # flash kernel applies after a concat.  Fold the latent-space
        # scale into q (the kernel scales by head_dim^-0.5 itself).
        from repro.kernels.ops import flash_decode
        q_cat = jnp.concatenate(
            [q_lat, q_rope.astype(jnp.float32)], axis=-1)
        q_cat = q_cat * (scale * (r + dr) ** 0.5)
        k_cat = jnp.concatenate(
            [ckv, krope], axis=-1).astype(jnp.float32)[:, :, None, :]
        o_lat = flash_decode(q_cat, k_cat,
                             ckv.astype(jnp.float32)[:, :, None, :],
                             lens=pos + 1, cap=cfg.attn_softcap)
    else:
        o_lat = _mla_decode_core(q_lat, q_rope, ckv, krope, pos + 1,
                                 scale=scale, cap=cfg.attn_softcap,
                                 axis=None)
    wuv = params["wuv"].reshape(r, H, dv)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wuv.astype(jnp.float32))
    out = out.astype(x.dtype)
    return x_out(cfg, params, out, B, 1), MLACache(ckv, krope)


def _mla_decode_core(q_lat, q_rope, ckv, krope, valid, *, scale, cap,
                     axis=None):
    """Latent-space decode attention; seq-parallel when ``axis`` given
    (ckv/krope shard-local over L, combine with pmax/psum)."""
    L_loc = ckv.shape[1]
    s = jnp.einsum("bqhr,bjr->bhqj", q_lat, ckv.astype(jnp.float32))
    s = s + jnp.einsum("bqhd,bjd->bhqj", q_rope.astype(jnp.float32),
                       krope.astype(jnp.float32))
    s = s * scale
    s = softcap(s, cap)
    offset = jax.lax.axis_index(axis) * L_loc if axis else 0
    valid = jnp.asarray(valid)
    if valid.ndim == 1:
        valid = valid.reshape(-1, 1, 1, 1)
    mask = (offset + jnp.arange(L_loc))[None, None, None, :] < valid
    s = jnp.where(mask, s, NEG_INF)
    if axis is None:
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqj,bjr->bqhr", p, ckv.astype(jnp.float32))
    m_loc = jnp.max(s, axis=-1)
    m_glob = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(s - m_glob[..., None]) * mask
    l_loc = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqj,bjr->bqhr", p, ckv.astype(jnp.float32))
    l_glob = jax.lax.psum(l_loc, axis)
    acc = jax.lax.psum(acc, axis)
    return acc / jnp.maximum(l_glob, 1e-30).transpose(0, 2, 1)[..., None]


def _mla_shard_map_decode(q_lat, q_rope, ckv, krope, valid, *, scale, cap,
                          seq_shard):
    from jax.sharding import PartitionSpec as P
    axis = seq_shard["axis"]
    dp = tuple(seq_shard.get("dp", ()) or ())
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    qspec = P(bspec, None, None, None)
    cspec = P(bspec, axis, None)

    def body(ql, qr, c, kr, val):
        return _mla_decode_core(ql, qr, c, kr, val, scale=scale, cap=cap,
                                axis=axis)

    return shard_map(body, mesh=seq_shard.get("mesh"),
                         in_specs=(qspec, qspec, cspec, cspec, P()),
                         out_specs=qspec)(q_lat, q_rope, ckv, krope, valid)
