"""One decoder/encoder layer = pre-norm mixer (+optional cross-attn) + FFN.

``LayerSpec.kind`` selects the mixer (attn / mamba / mlstm / slstm),
``LayerSpec.ffn`` selects dense FFN, MoE, or none (xLSTM blocks carry their
own projections).  Gemma2-style ``post_norms`` adds norms after each
sublayer output before the residual add.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import init_rmsnorm, rmsnorm


class LayerAux(NamedTuple):
    load_balance: jax.Array
    router_z: jax.Array
    dropped_frac: jax.Array


def zero_aux() -> LayerAux:
    return LayerAux(jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))


def init_layer(cfg, spec, rng, dtype, *, cross: bool = False):
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    p: dict = {"ln1": init_rmsnorm(d, dtype)}
    if spec.kind == "attn":
        p["mixer"] = (attn.init_mla(cfg, ks[0], dtype) if cfg.use_mla
                      else attn.init_gqa(cfg, ks[0], dtype))
    elif spec.kind == "mamba":
        p["mixer"] = ssm_mod.init_mamba(cfg, ks[0], dtype)
    elif spec.kind == "mlstm":
        p["mixer"] = xlstm_mod.init_mlstm(cfg, ks[0], dtype)
    elif spec.kind == "slstm":
        p["mixer"] = xlstm_mod.init_slstm(cfg, ks[0], dtype)
    if cross:
        p["ln_cross"] = init_rmsnorm(d, dtype)
        p["cross"] = attn.init_gqa(cfg, ks[1], dtype, cross=True)
    if spec.ffn == "dense":
        p["ln2"] = init_rmsnorm(d, dtype)
        p["ffn"] = ffn_mod.init_ffn(cfg, ks[2], dtype)
    elif spec.ffn == "moe":
        p["ln2"] = init_rmsnorm(d, dtype)
        p["ffn"] = moe_mod.init_moe(cfg, ks[2], dtype)
    if cfg.post_norms:
        p["pn1"] = init_rmsnorm(d, dtype)
        if spec.ffn != "none":
            p["pn2"] = init_rmsnorm(d, dtype)
    return p


def layer_cache_spec(cfg, spec, batch: int, max_len: int, dtype,
                     *, cross_len: int = 0):
    c: dict = {}
    if spec.kind == "attn":
        c["mixer"] = (attn.mla_cache_spec(cfg, batch, max_len, dtype)
                      if cfg.use_mla
                      else attn.gqa_cache_spec(cfg, spec, batch, max_len,
                                               dtype))
    elif spec.kind == "mamba":
        c["mixer"] = ssm_mod.mamba_cache_spec(cfg, batch, dtype)
    elif spec.kind == "mlstm":
        c["mixer"] = xlstm_mod.mlstm_cache_spec(cfg, batch, dtype)
    elif spec.kind == "slstm":
        c["mixer"] = xlstm_mod.slstm_cache_spec(cfg, batch, dtype)
    if cross_len:
        K, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
        z = jnp.zeros((batch, cross_len, K, Dh), dtype)
        c["cross"] = (z, z)
    return c


def apply_layer(cfg, spec, params, x, *, positions, mode, cache=None,
                pos=None, memory=None, chunkwise: bool = True,
                use_pallas: bool = False, causal: bool = True,
                seq_shard=None):
    """Returns (x, new_cache, LayerAux)."""
    eps = cfg.norm_eps
    new_cache: dict = {}
    aux = zero_aux()

    h = rmsnorm(params["ln1"], x, eps)
    mixer_cache = None if cache is None else cache.get("mixer")
    if spec.kind == "attn":
        if cfg.use_mla:
            h, mc = attn.apply_mla(cfg, spec, params["mixer"], h,
                                   positions=positions, mode=mode,
                                   cache=mixer_cache, pos=pos,
                                   seq_shard=seq_shard,
                                   use_pallas=use_pallas)
        else:
            h, mc = attn.apply_gqa(cfg, spec, params["mixer"], h,
                                   positions=positions, mode=mode,
                                   cache=mixer_cache, pos=pos, causal=causal,
                                   seq_shard=seq_shard,
                                   use_pallas=use_pallas)
    elif spec.kind == "mamba":
        h, mc = ssm_mod.apply_mamba(cfg, params["mixer"], h, mode=mode,
                                    cache=mixer_cache)
    elif spec.kind == "mlstm":
        h, mc = xlstm_mod.apply_mlstm(cfg, params["mixer"], h, mode=mode,
                                      cache=mixer_cache, chunkwise=chunkwise)
    elif spec.kind == "slstm":
        h, mc = xlstm_mod.apply_slstm(cfg, params["mixer"], h, mode=mode,
                                      cache=mixer_cache)
    else:  # pragma: no cover
        raise ValueError(spec.kind)
    if cfg.post_norms:
        h = rmsnorm(params["pn1"], h, eps)
    x = x + h
    if mc is not None:
        new_cache["mixer"] = mc
    elif cache is not None and "mixer" in cache:
        new_cache["mixer"] = cache["mixer"]

    if "cross" in params:
        h = rmsnorm(params["ln_cross"], x, eps)
        mem_kv = None if cache is None else cache.get("cross")
        if mode == "decode":
            h, kv = attn.apply_cross_attention(cfg, params["cross"], h, None,
                                               mem_cache=mem_kv)
        else:
            h, kv = attn.apply_cross_attention(cfg, params["cross"], h,
                                               memory)
        x = x + h
        if mode in ("prefill", "decode"):
            new_cache["cross"] = kv

    if spec.ffn != "none":
        h = rmsnorm(params["ln2"], x, eps)
        if spec.ffn == "dense":
            h = ffn_mod.apply_ffn(cfg, params["ffn"], h)
        else:
            h, moe_aux = moe_mod.apply_moe(
                cfg, params["ffn"], h, use_pallas_gmm=use_pallas,
                shardmap_ok=(mode != "train"))
            aux = LayerAux(*[jnp.asarray(a, jnp.float32) for a in moe_aux])
        if cfg.post_norms:
            h = rmsnorm(params["pn2"], h, eps)
        x = x + h

    return x, (new_cache or None), aux
