"""Full sequence models for every assigned architecture.

Layout:  optional encoder stack (Seamless), then a decoder stack made of
``cfg.prefix`` unrolled layers + ``cfg.pattern`` repeated ``num_repeats``
times via ``lax.scan`` over stacked params (keeps HLO size independent of
depth).  Three entry points share weights:

  ``loss_fn``      -- train-mode forward + CE (+ MoE aux, + MTP).
  ``prefill``      -- populate KV/state caches from a prompt.
  ``decode_step``  -- one token against the caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.blocks import LayerAux, zero_aux
from repro.models.common import (cross_entropy, dense_init, embed_init,
                                 init_rmsnorm, rmsnorm, softcap)

MTP_WEIGHT = 0.3


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stacked_init(cfg, spec, rng, n, dtype, **kw):
    keys = jax.random.split(rng, n)
    return jax.vmap(lambda k: blocks.init_layer(cfg, spec, k, dtype, **kw))(
        keys)


def init_model(cfg, rng, dtype=jnp.float32):
    ks = jax.random.split(rng, 8)
    cross = cfg.is_encdec
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size,
                                       dtype)
    params["prefix"] = tuple(
        blocks.init_layer(cfg, spec, k, dtype, cross=cross)
        for spec, k in zip(cfg.prefix,
                           jax.random.split(ks[2], max(1, len(cfg.prefix)))))
    params["pattern"] = tuple(
        _stacked_init(cfg, spec, k, cfg.num_repeats, dtype, cross=cross)
        for spec, k in zip(cfg.pattern,
                           jax.random.split(ks[3], len(cfg.pattern))))
    if cfg.is_encdec:
        from repro.configs.base import LayerSpec
        enc_spec = LayerSpec(kind="attn", ffn="dense")
        params["encoder"] = _stacked_init(cfg, enc_spec, ks[4],
                                          cfg.encoder_layers, dtype)
        params["enc_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if cfg.mtp:
        from repro.configs.base import LayerSpec
        mtp_spec = LayerSpec(kind="attn", ffn="dense")
        params["mtp"] = {
            "proj": dense_init(ks[5], 2 * cfg.d_model, cfg.d_model, dtype),
            "norm": init_rmsnorm(cfg.d_model, dtype),
            "layer": blocks.init_layer(cfg, mtp_spec, ks[6], dtype),
        }
    return params


def param_shapes(cfg, dtype=jnp.float32):
    """Parameter ShapeDtypeStructs without allocating (for dry-run)."""
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: init_model(cfg, rng, dtype))


def param_count(cfg) -> int:
    import math
    shapes = param_shapes(cfg)
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_param_count(cfg) -> int:
    """MoE: parameters touched per token (routed top-k + shared + dense)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    f = cfg.moe_d_ff or cfg.d_ff
    n_moe_layers = (sum(1 for s in cfg.pattern if s.ffn == "moe")
                    * cfg.num_repeats
                    + sum(1 for s in cfg.prefix if s.ffn == "moe"))
    per_expert = 3 * cfg.d_model * f if cfg.gated_ffn else 2 * cfg.d_model * f
    inactive = n_moe_layers * (cfg.num_experts - cfg.experts_per_token) \
        * per_expert
    return total - inactive


# ---------------------------------------------------------------------------
# encoder (Seamless)
# ---------------------------------------------------------------------------

def encode(cfg, params, frontend_embeds, *, chunkwise=True, unroll=1):
    """Bidirectional encoder over stub frontend embeddings (B, M, d)."""
    from repro.configs.base import LayerSpec
    enc_spec = LayerSpec(kind="attn", ffn="dense")
    B, M, _ = frontend_embeds.shape
    x = frontend_embeds
    positions = jnp.broadcast_to(jnp.arange(M), (B, M))

    def body(x, layer_params):
        x, _, _ = blocks.apply_layer(cfg, enc_spec, layer_params, x,
                                     positions=positions, mode="train",
                                     causal=False, chunkwise=chunkwise)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=unroll)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder stack
# ---------------------------------------------------------------------------

def _pin_batch(x):
    """GSPMD hygiene: re-pin the residual stream's batch dim to the
    data-parallel mesh axes at layer boundaries (serve path).  Without
    this, sharding propagation can drop the batch sharding after
    gather/scatter-heavy layers (MoE dispatch) and replicate whole layers
    across the data axes."""
    try:
        from jax.sharding import PartitionSpec as P
        mesh = jax.sharding.get_abstract_mesh()
        names = mesh.axis_names or ()
        dp = tuple(a for a in ("pod", "data") if a in names)
        if not dp:
            return x
        sizes = dict(zip(names, mesh.axis_sizes))
        n = 1
        for a in dp:
            n *= sizes[a]
        if x.shape[0] % n:
            return x
        spec = P(dp if len(dp) > 1 else dp[0], *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def _sum_aux(a: LayerAux, b: LayerAux) -> LayerAux:
    return LayerAux(*(x + y for x, y in zip(a, b)))


def run_decoder(cfg, params, x, *, positions, mode, cache=None, pos=None,
                memory=None, chunkwise=True, use_pallas=False, unroll=1,
                seq_shard=None, remat=False):
    """x: (B,S,d) embeddings.  Returns (hidden, new_cache, aux)."""
    aux = zero_aux()
    new_prefix = []
    for i, spec in enumerate(cfg.prefix):
        c = None if cache is None else cache["prefix"][i]
        x, nc, a = blocks.apply_layer(
            cfg, spec, params["prefix"][i], x, positions=positions,
            mode=mode, cache=c, pos=pos, memory=memory,
            chunkwise=chunkwise, use_pallas=use_pallas,
            seq_shard=seq_shard)
        aux = _sum_aux(aux, a)
        new_prefix.append(nc)

    def unit(carry, xs):
        x, aux = carry
        if cache is None:
            unit_params, unit_cache = xs, (None,) * len(cfg.pattern)
        else:
            unit_params, unit_cache = xs
        new_unit_cache = []
        for i, spec in enumerate(cfg.pattern):
            if mode in ("prefill", "decode"):
                x = _pin_batch(x)
            x, nc, a = blocks.apply_layer(
                cfg, spec, unit_params[i], x, positions=positions,
                mode=mode, cache=unit_cache[i], pos=pos, memory=memory,
                chunkwise=chunkwise, use_pallas=use_pallas,
                seq_shard=seq_shard)
            aux = _sum_aux(aux, a)
            new_unit_cache.append(nc)
        ys = tuple(new_unit_cache) if any(
            c is not None for c in new_unit_cache) else None
        return (x, aux), ys

    xs = params["pattern"] if cache is None \
        else (params["pattern"], cache["pattern"])
    body = jax.checkpoint(unit) if remat else unit
    (x, aux), pattern_cache = jax.lax.scan(body, (x, aux), xs,
                                           unroll=unroll)
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"prefix": tuple(new_prefix), "pattern": pattern_cache}
    return x, new_cache, aux


def _lm_logits(cfg, params, x):
    head = params["lm_head"] if not cfg.tie_embeddings \
        else params["embed"].T
    return x @ head


def _embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * (cfg.d_model ** 0.5)
    return x


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, batch, *, chunkwise=True, use_pallas=False,
            unroll=1, remat=False):
    """batch: tokens (B,S), labels (B,S) [= next token], optional
    frontend (B,M,d), optional loss_mask (B,S).  Returns (loss, metrics)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    memory = None
    n_front = 0

    if cfg.is_encdec:
        memory = encode(cfg, params, batch["frontend"], chunkwise=chunkwise,
                        unroll=unroll)
    elif cfg.frontend is not None:
        front = batch["frontend"]  # (B, P, d) projected patch embeddings
        n_front = front.shape[1]
        x = jnp.concatenate([front.astype(x.dtype), x], axis=1)

    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (B, x.shape[1]))
    x, _, aux = run_decoder(cfg, params, x, positions=positions,
                            mode="train", memory=memory,
                            chunkwise=chunkwise, use_pallas=use_pallas,
                            unroll=unroll, remat=remat)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_front:
        x = x[:, n_front:]
    logits = _lm_logits(cfg, params, x)
    mask = batch.get("loss_mask")
    ce = cross_entropy(logits, labels, mask, logit_cap=cfg.logit_softcap)
    loss = ce
    # next-token accuracy (softcap is monotone, so argmax ignores it);
    # lax.stop_gradient-free: argmax carries no gradient anyway
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    if mask is None:
        acc = hit.mean()
    else:
        m32 = mask.astype(jnp.float32)
        acc = jnp.sum(hit * m32) / jnp.maximum(jnp.sum(m32), 1.0)
    metrics = {"ce": ce, "acc": acc}

    n_moe = (sum(1 for s in cfg.pattern if s.ffn == "moe") * cfg.num_repeats
             + sum(1 for s in cfg.prefix if s.ffn == "moe"))
    if n_moe:
        lb = aux.load_balance / n_moe
        rz = aux.router_z / n_moe
        loss = loss + cfg.router_aux_coef * lb + cfg.router_z_coef * rz
        metrics.update(load_balance=lb, router_z=rz,
                       dropped_frac=aux.dropped_frac / n_moe)

    if cfg.mtp:
        # DeepSeek MTP: h'_t = Layer(proj([h_t ; emb(tok_{t+1})])), predict
        # tok_{t+2}.  labels[t] = tok_{t+1}  =>  emb(labels)[:, :-1] pairs
        # with x[:, :-1] to predict labels[:, 1:].
        mtp = params["mtp"]
        nxt = _embed_tokens(cfg, params, labels[:, :-1])
        h = jnp.concatenate([x[:, :-1], nxt], axis=-1) @ mtp["proj"]
        h = rmsnorm(mtp["norm"], h, cfg.norm_eps)
        from repro.configs.base import LayerSpec
        h, _, _ = blocks.apply_layer(
            cfg, LayerSpec(kind="attn", ffn="dense"), mtp["layer"], h,
            positions=positions[:, :S - 1], mode="train",
            chunkwise=chunkwise)
        mtp_logits = _lm_logits(cfg, params, h)
        mtp_ce = cross_entropy(mtp_logits, labels[:, 1:],
                               logit_cap=cfg.logit_softcap)
        loss = loss + MTP_WEIGHT * mtp_ce
        metrics["mtp_ce"] = mtp_ce

    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serve: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.float32,
               cross_len: int = 0):
    cross_len = cross_len or (cfg.frontend_tokens if cfg.is_encdec else 0)

    def one(spec):
        return blocks.layer_cache_spec(cfg, spec, batch, max_len, dtype,
                                       cross_len=cross_len)

    prefix = tuple(one(s) for s in cfg.prefix)

    def stacked(spec):
        c = one(spec)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.num_repeats,) + a.shape, a.dtype), c)

    pattern = tuple(stacked(s) for s in cfg.pattern)
    return {"prefix": prefix, "pattern": pattern}


def prefill(cfg, params, batch, cache, *, chunkwise=True, use_pallas=False,
            unroll=1, lens=None):
    """Populate caches from a prompt.  Returns (last_logits, cache).

    ``lens``: optional (B,) per-row prompt lengths for right-padded mixed
    batches -- logits are gathered at each row's last *real* token (cache
    rows past a row's length hold pad garbage, but decode masks them via
    per-row valid lengths and overwrites them as the row generates)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    memory = None
    n_front = 0
    if cfg.is_encdec:
        memory = encode(cfg, params, batch["frontend"], chunkwise=chunkwise,
                        unroll=unroll)
    elif cfg.frontend is not None and "frontend" in batch:
        front = batch["frontend"]
        n_front = front.shape[1]
        x = jnp.concatenate([front.astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (B, x.shape[1]))
    x, new_cache, _ = run_decoder(cfg, params, x, positions=positions,
                                  mode="prefill", cache=cache, memory=memory,
                                  chunkwise=chunkwise, use_pallas=use_pallas,
                                  unroll=unroll)
    if lens is not None:
        idx = jnp.asarray(lens, jnp.int32).reshape(-1, 1, 1) - 1 + n_front
        x = jnp.take_along_axis(x, jnp.clip(idx, 0, x.shape[1] - 1), axis=1)
    else:
        x = x[:, -1:]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = softcap(_lm_logits(cfg, params, x), cfg.logit_softcap)
    return logits, new_cache


def decode_step(cfg, params, cache, tokens, pos, *, chunkwise=True,
                unroll=1, seq_shard=None, use_pallas=False):
    """tokens: (B,1) int32, pos: global position of each token -- a
    scalar int32, or a (B,) vector for mixed-length slot batches.

    Returns (logits (B,1,V), new_cache)."""
    B = tokens.shape[0]
    x = _embed_tokens(cfg, params, tokens)
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos.reshape(-1, 1), (B, 1))
    x, new_cache, _ = run_decoder(cfg, params, x, positions=positions,
                                  mode="decode", cache=cache, pos=pos,
                                  chunkwise=chunkwise, unroll=unroll,
                                  seq_shard=seq_shard, use_pallas=use_pallas)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = softcap(_lm_logits(cfg, params, x), cfg.logit_softcap)
    return logits, new_cache
