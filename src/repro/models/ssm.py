"""Mamba-1 selective SSM block (Jamba's recurrent layer).

TPU adaptation: the CUDA selective-scan kernel becomes a
``jax.lax.associative_scan`` over time (parallel prefix tree -- the TPU
idiom for linear recurrences); decode is the O(1) single-step recurrence.
The depthwise causal conv is expressed as a sum of shifted slices (kernel
size 4), which XLA fuses.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


class MambaCache(NamedTuple):
    conv: jax.Array  # (B, conv_dim-1, d_inner)  last inputs
    ssm: jax.Array   # (B, d_inner, N)


def _dims(cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state_dim
    dt_rank = max(1, math.ceil(d / 16))
    return d, di, N, dt_rank


def init_mamba(cfg, rng, dtype):
    d, di, N, dt_rank = _dims(cfg)
    c = cfg.ssm_conv_dim
    ks = jax.random.split(rng, 6)
    # S4D-real A initialization: A_n = -(n+1)
    a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": dense_init(ks[1], c, di, dtype, shape=(c, di)),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * N, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.full((di,), math.log(math.e - 1), dtype),  # softplus->1
        "A_log": jnp.log(a).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _causal_conv(params, u, conv_state=None):
    """u: (B,S,di).  Returns conv output and new conv state (last c-1 rows)."""
    c = params["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], c - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    xp = jnp.concatenate([pad, u], axis=1)  # (B, S+c-1, di)
    S = u.shape[1]
    out = sum(xp[:, i:i + S] * params["conv_w"][i] for i in range(c))
    out = out + params["conv_b"]
    new_state = xp[:, -(c - 1):]
    return out, new_state


def _ssm_inputs(cfg, params, x):
    """x: (B,S,di) conv+silu output -> dt (B,S,di), B/C (B,S,N)."""
    _, _, N, dt_rank = _dims(cfg)
    proj = x @ params["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ params["dt_proj"]
                         + params["dt_bias"])
    Bm = proj[..., dt_rank:dt_rank + N]
    Cm = proj[..., dt_rank + N:]
    return dt, Bm, Cm


def mamba_cache_spec(cfg, batch: int, dtype):
    _, di, N, _ = _dims(cfg)
    c = cfg.ssm_conv_dim
    return MambaCache(conv=jnp.zeros((batch, c - 1, di), dtype),
                      ssm=jnp.zeros((batch, di, N), jnp.float32))


def apply_mamba(cfg, params, x, *, mode, cache=None):
    """x: (B,S,d) -> (out, new_cache)."""
    B, S, d = x.shape
    _, di, N, _ = _dims(cfg)
    xz = x @ params["in_proj"]
    u, z = xz[..., :di], xz[..., di:]

    if mode in ("train", "prefill"):
        conv_in = None if mode == "train" else cache.conv * 0  # fresh ctx
        cu, conv_state = _causal_conv(params, u, conv_in)
        cu = jax.nn.silu(cu)
        dt, Bm, Cm = _ssm_inputs(cfg, params, cu)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (di,N)
        dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)  # (B,S,di,N)
        dBx = (dt * cu).astype(jnp.float32)[..., None] * \
            Bm.astype(jnp.float32)[:, :, None, :]
        # h_t = dA_t h_{t-1} + dBx_t  via parallel prefix
        _, hs = jax.lax.associative_scan(
            lambda a, b: (b[0] * a[0], b[0] * a[1] + b[1]), (dA, dBx), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cm.astype(jnp.float32))
        y = (y + params["D"].astype(jnp.float32) * cu).astype(x.dtype)
        y = y * jax.nn.silu(z)
        out = y @ params["out_proj"]
        new_cache = None
        if mode == "prefill":
            new_cache = MambaCache(conv=conv_state.astype(cache.conv.dtype),
                                   ssm=hs[:, -1])
        return out, new_cache

    # decode: single token
    cu, conv_state = _causal_conv(params, u, cache.conv)
    cu = jax.nn.silu(cu)
    dt, Bm, Cm = _ssm_inputs(cfg, params, cu)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32)[:, 0, :, None] * A)  # (B,di,N)
    dBx = (dt * cu).astype(jnp.float32)[:, 0, :, None] * \
        Bm.astype(jnp.float32)[:, 0, None, :]
    h = dA * cache.ssm + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))
    y = (y + params["D"].astype(jnp.float32) * cu[:, 0]).astype(x.dtype)
    y = (y * jax.nn.silu(z[:, 0]))[:, None]
    out = y @ params["out_proj"]
    return out, MambaCache(conv=conv_state.astype(cache.conv.dtype), ssm=h)
