"""Mixture-of-Experts with static-shape capacity dispatch.

TPU adaptation: instead of CUDA-style dynamic token routing, tokens are
placed into a static (E, capacity, d) buffer via scatter (GSPMD-friendly;
the expert dim shards over the 'model'/'expert' mesh axis and the buffer
transfer lowers to an all-to-all under expert parallelism).  Expert compute
is a grouped matmul ``ecd,edf->ecf`` -- the target of the ``gmm`` Pallas
kernel.  Aux load-balance loss + router z-loss are returned for training.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init


class MoEAux(NamedTuple):
    load_balance: jax.Array  # scalar
    router_z: jax.Array      # scalar
    dropped_frac: jax.Array  # diagnostics: fraction of routed slots dropped


def init_moe(cfg, rng, dtype):
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], d, E, dtype),
        "we_gate": dense_init(ks[1], d, f, dtype, shape=(E, d, f)),
        "we_up": dense_init(ks[2], d, f, dtype, shape=(E, d, f)),
        "we_down": dense_init(ks[3], f, d, dtype, shape=(E, f, d)),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kss[0], d, fs, dtype),
            "w_up": dense_init(kss[1], d, fs, dtype),
            "w_down": dense_init(kss[2], fs, d, dtype),
        }
    return p


def _capacity(cfg, tokens: int) -> int:
    cap = int(cfg.capacity_factor * tokens * cfg.experts_per_token
              / cfg.num_experts)
    return max(8, min(tokens, (cap + 7) // 8 * 8))  # multiple of 8, <= T


def _expert_axis_constraint(t):
    """Pin the expert (leading) dim of dispatch buffers to the 'model'
    mesh axis when lowering under a mesh that has one.  Without this GSPMD
    replicates the scatter-produced buffer on every device and the expert
    matmul runs ~E-fold redundantly (observed in the baseline dry-runs)."""
    try:
        from jax.sharding import PartitionSpec as P
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names or \
                "model" not in mesh.axis_names:
            return t
        msize = dict(zip(mesh.axis_names, mesh.axis_sizes))["model"]
        if t.shape[0] % msize:
            return t
        spec = P("model", *([None] * (t.ndim - 1)))
        return jax.lax.with_sharding_constraint(t, spec)
    except Exception:  # no mesh context (simulation regime)
        return t


def _shardmap_plan(cfg, n_tokens: int):
    """Return (data_axes, model_axis) for the shard_map expert-parallel
    path when the ambient mesh supports it, else None."""
    import os as _os
    if _os.environ.get("REPRO_MOE_SHARDMAP", "1") == "0":
        return None
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = mesh.axis_names or ()
        if "model" not in names:
            return None
        sizes = dict(zip(names, mesh.axis_sizes))
        if cfg.num_experts % sizes["model"]:
            return None
        data_axes = tuple(a for a in ("pod", "data") if a in names)
        total = sizes["model"]
        for a in data_axes:
            total *= sizes[a]
        if n_tokens % total:
            return None
        if (n_tokens // total) * cfg.experts_per_token < 8:
            return None  # too few local slots to be meaningful
        return data_axes, "model"
    except Exception:
        return None


def apply_moe(cfg, params, x, *, use_pallas_gmm: bool = False,
              expert_sharding: bool = True, shardmap_ok: bool = False):
    """x: (B, S, d) -> (out, MoEAux)."""
    B, S, d = x.shape
    if shardmap_ok:
        plan = _shardmap_plan(cfg, B * S)
        if plan is not None:
            from repro.models.moe_shardmap import apply_moe_shardmap
            data_axes, model_axis = plan
            return apply_moe_shardmap(cfg, params, x,
                                      data_axes=data_axes,
                                      model_axis=model_axis)
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_token
    cap = _capacity(cfg, T)
    act = activation(cfg.act)
    xt = x.reshape(T, d)

    logits = (xt @ params["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert queue.  Two
    # formulations with identical results (stable order = token-major):
    #  * one-hot + cumsum over the (T*k, E) matrix -- O(T*k*E) work that
    #    XLA:SPMD executes catastrophically when the token axis is
    #    sharded (measured 331s/353s of deepseek-v3 prefill compute);
    #  * stable argsort by expert id + rank-within-group -- O(N log N).
    # The sort formulation is the default; REPRO_MOE_CUMSUM=1 restores
    # the naive one for A/B dry-runs.
    flat_e = eidx.reshape(-1)  # (T*k,) row-major: token-major order
    import os as _os
    if _os.environ.get("REPRO_MOE_CUMSUM", "0") == "1":
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)  # exclusive cumsum
        pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    else:
        n_assign = flat_e.shape[0]
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
        starts = jnp.cumsum(counts) - counts  # exclusive per-expert start
        ranks = jnp.arange(n_assign, dtype=jnp.int32) - starts[sorted_e]
        pos = jnp.zeros((n_assign,), jnp.int32).at[order].set(ranks)
    keep = pos < cap

    # scatter tokens into (E, cap, d)
    tok_id = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E, cap, d), x.dtype)
    safe_pos = jnp.where(keep, pos, cap - 1)
    contrib = jnp.where(keep[:, None], xt[tok_id], 0)
    buf = buf.at[flat_e, safe_pos].add(contrib)
    # env toggle so dry-run A/B comparisons don't need arg threading
    import os as _os
    if expert_sharding and _os.environ.get("REPRO_MOE_EXPERT_SHARD",
                                           "1") != "0":
        buf = _expert_axis_constraint(buf)

    # grouped expert FFN (the gmm kernel target)
    if use_pallas_gmm:
        from repro.kernels.ops import gmm
        h = act(gmm(buf, params["we_gate"])) * gmm(buf, params["we_up"])
        y = gmm(h, params["we_down"])
    else:
        h = act(jnp.einsum("ecd,edf->ecf", buf, params["we_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
        y = jnp.einsum("ecf,efd->ecd", h, params["we_down"])

    # gather back with combine weights
    picked = y[flat_e, safe_pos]  # (T*k, d)
    w = (gates.reshape(-1) * keep).astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok_id].add(picked * w[:, None])

    if cfg.num_shared_experts:
        sp = params["shared"]
        h = act(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        out = out + h @ sp["w_down"]

    # aux losses
    me = probs.mean(0)  # mean router prob per expert
    ce = (jax.nn.one_hot(eidx, E).sum(1).mean(0) / k)  # fraction routed
    load_balance = E * jnp.sum(me * ce)
    router_z = jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)
    dropped = 1.0 - keep.mean()
    return out.reshape(B, S, d), MoEAux(load_balance, router_z, dropped)
