"""Shared primitive layers: norms, RoPE, activations, initializers.

All models are pure-function pytrees: ``init_*`` builds a nested dict of
jnp arrays, ``apply``-style functions are stateless.  Initializers use
truncated-normal with 1/sqrt(fan_in) scale.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, fan_in: int, fan_out: int, dtype=jnp.float32, *,
               shape=None):
    """Scaled normal init; ``shape`` overrides (fan_in, fan_out)."""
    shape = shape if shape is not None else (fan_in, fan_out)
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.truncated_normal(rng, -2.0, 2.0, (vocab, d),
                                        jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}  # (1 + scale) parametrization


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(dim: int, theta: float):
    """Inverse frequencies for rotary embedding (half-dim)."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) rotated by ``positions`` (..., S) or (S,)."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (d/2,)
    pos = positions.astype(jnp.float32)
    angles = pos[..., None] * inv  # (..., S, d/2)
    # broadcast over head dim: (..., S, 1, d/2)
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, mask=None, logit_cap: Optional[float] = None):
    """Mean token cross entropy.  logits (..., V) float, labels (...) int."""
    logits = softcap(logits.astype(jnp.float32), logit_cap)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
