"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, true recurrence) with exponential gating and
max-state stabilization.

TPU adaptation: no warp-level primitives -- the mLSTM train path offers two
formulations validated against each other: a recurrent ``lax.scan``
(baseline/oracle, also the decode step) and a *chunkwise* form (intra-chunk
quadratic + inter-chunk state carry, the linear-attention chunking idiom
that feeds the MXU).  sLSTM is inherently sequential: ``lax.scan`` over
time with a block-diagonal (per-head) recurrent matrix.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, init_rmsnorm, rmsnorm


def _logsig(x):
    return -jax.nn.softplus(-x)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMCache(NamedTuple):
    C: jax.Array  # (B, H, dh, dh) matrix memory
    n: jax.Array  # (B, H, dh)     normalizer
    m: jax.Array  # (B, H)         stabilizer (log space)
    conv: jax.Array  # (B, c-1, di) conv tail


def _mdims(cfg):
    d = cfg.d_model
    di = cfg.mlstm_expand * d
    H = cfg.num_heads
    return d, di, H, di // H


def init_mlstm(cfg, rng, dtype):
    d, di, H, dh = _mdims(cfg)
    c = 4
    ks = jax.random.split(rng, 8)
    return {
        "up": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": dense_init(ks[1], c, di, dtype, shape=(c, di)),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_if": dense_init(ks[5], di, 2 * H, dtype),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]
                                ).astype(dtype),
        "norm": init_rmsnorm(di, dtype),
        "down": dense_init(ks[6], di, d, dtype),
    }


def _mlstm_qkvif(cfg, params, x, conv_state=None):
    from repro.models.ssm import _causal_conv
    d, di, H, dh = _mdims(cfg)
    B, S, _ = x.shape
    xz = x @ params["up"]
    xi, z = xz[..., :di], xz[..., di:]
    cx, conv_state = _causal_conv(
        {"conv_w": params["conv_w"], "conv_b": params["conv_b"]}, xi,
        conv_state)
    cx = jax.nn.silu(cx)
    q = (cx @ params["wq"]).reshape(B, S, H, dh)
    k = (cx @ params["wk"]).reshape(B, S, H, dh) * (dh ** -0.5)
    v = (xi @ params["wv"]).reshape(B, S, H, dh)
    gates = (cx @ params["w_if"] + params["b_if"]).astype(jnp.float32)
    ig, fg = gates[..., :H], gates[..., H:]  # (B,S,H) raw
    return q, k, v, ig, _logsig(fg), z, conv_state


def mlstm_cache_spec(cfg, batch: int, dtype):
    d, di, H, dh = _mdims(cfg)
    return MLSTMCache(C=jnp.zeros((batch, H, dh, dh), jnp.float32),
                      n=jnp.zeros((batch, H, dh), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32),
                      conv=jnp.zeros((batch, 3, di), dtype))


def _mlstm_step(state, inp):
    """One recurrent step.  q,k,v: (B,H,dh); i,f raw/log gates (B,H)."""
    C, n, m = state
    q, k, v, ig, lf = inp
    m_new = jnp.maximum(lf + m, ig)
    i_p = jnp.exp(ig - m_new)[..., None]
    f_p = jnp.exp(lf + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (
        k[..., :, None] * v[..., None, :])
    n = f_p * n + i_p * k
    h_num = jnp.einsum("bhij,bhi->bhj", C, q.astype(jnp.float32))
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, q)),
                        jnp.exp(-m_new))[..., None]
    h = h_num / denom
    return (C, n, m_new), h


def mlstm_recurrent(q, k, v, ig, lf, state):
    """Scan over time.  q..: (B,S,H,dh), gates (B,S,H).  Oracle path."""
    def step(carry, inp):
        return _mlstm_step(carry, inp)
    xs = (q.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          ig.transpose(1, 0, 2), lf.transpose(1, 0, 2))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), state  # (B,S,H,dh)


def mlstm_chunkwise(q, k, v, ig, lf, state, chunk: int = 256):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic attention with decay
    mask + inter-chunk matrix-state recurrence.  MXU-friendly."""
    B, S, H, dh = q.shape
    L = min(chunk, S)
    while S % L:
        L //= 2
    nC = S // L

    qf = q.astype(jnp.float32).reshape(B, nC, L, H, dh)
    kf = k.astype(jnp.float32).reshape(B, nC, L, H, dh)
    vf = v.astype(jnp.float32).reshape(B, nC, L, H, dh)
    igc = ig.reshape(B, nC, L, H)
    lfc = lf.reshape(B, nC, L, H)

    def chunk_step(carry, inp):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qc, kc, vc, ic, fc = inp  # (B,L,H,dh), gates (B,L,H)
        F = jnp.cumsum(fc, axis=1)  # inclusive logcumsum of forget gates
        Ftot = F[:, -1]  # (B,H)
        # log weights of each source position s surviving to chunk end
        lw = ic + (Ftot[:, None] - F)  # (B,L,H)
        m_next = jnp.maximum(Ftot + m, jnp.max(lw, axis=1))
        # --- inter-chunk: contribution of carried state to queries
        #   decay to position t: exp(F_t + m - m_next)
        dec_q = jnp.exp(F + (m - m_next)[:, None])  # (B,L,H)
        h_inter = jnp.einsum("bhij,blhi->blhj", C, qc) * dec_q[..., None]
        n_inter = jnp.einsum("bhi,blhi->blh", n, qc) * dec_q
        # --- intra-chunk: masked quadratic
        #   D[t,s] = exp(F_t - F_s + i_s - m_next)  for s <= t
        logD = (F[:, :, None] - F[:, None, :, :] + ic[:, None]
                - m_next[:, None, None])  # (B,L,L,H): [t,s]
        tri = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tri[None, :, :, None], jnp.exp(logD), 0.0)
        s_qk = jnp.einsum("blhi,bshi->blsh", qc, kc) * D
        h_intra = jnp.einsum("blsh,bshj->blhj", s_qk, vc)
        n_intra = jnp.einsum("blsh->blh", s_qk)
        # combine with max-stabilized normalizer
        num = h_inter + h_intra
        den = jnp.maximum(jnp.abs(n_inter + n_intra),
                          jnp.exp(-m_next)[:, None])
        h = num / den[..., None]
        # --- state update for next chunk
        wsrc = jnp.exp(lw - m_next[:, None])  # (B,L,H)
        C_new = jnp.exp(Ftot + m - m_next)[..., None, None] * C + \
            jnp.einsum("blhi,blhj->bhij", kc * wsrc[..., None], vc)
        n_new = jnp.exp(Ftot + m - m_next)[..., None] * n + \
            jnp.einsum("blhi->bhi", kc * wsrc[..., None])
        return (C_new, n_new, m_next), h

    xs = tuple(t.transpose(1, 0, 2, 3, 4) if t.ndim == 5
               else t.transpose(1, 0, 2, 3)
               for t in (qf, kf, vf, igc, lfc))
    state, hs = jax.lax.scan(chunk_step, state, xs)
    return hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh), state


def apply_mlstm(cfg, params, x, *, mode, cache=None, chunkwise=True):
    d, di, H, dh = _mdims(cfg)
    B, S, _ = x.shape
    conv_in = cache.conv if (mode == "decode") else None
    q, k, v, ig, lf, z, conv_state = _mlstm_qkvif(cfg, params, x, conv_in)

    if mode == "decode":
        state = (cache.C, cache.n, cache.m)
        state, h = _mlstm_step(
            state, (q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
                    v[:, 0].astype(jnp.float32), ig[:, 0], lf[:, 0]))
        h = h[:, None]
        new_cache = MLSTMCache(*state, conv=conv_state.astype(cache.conv.dtype))
    else:
        state = (jnp.zeros((B, H, dh, dh), jnp.float32),
                 jnp.zeros((B, H, dh), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))
        fn = mlstm_chunkwise if chunkwise else mlstm_recurrent
        h, state = fn(q, k, v, ig, lf, state)
        new_cache = None
        if mode == "prefill":
            new_cache = MLSTMCache(*state, conv=conv_state.astype(x.dtype))

    h = h.astype(x.dtype).reshape(B, S, di)
    h = rmsnorm(params["norm"], h, cfg.norm_eps)
    return (h * jax.nn.silu(z)) @ params["down"], new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMCache(NamedTuple):
    c: jax.Array  # (B, d)
    n: jax.Array  # (B, d)
    h: jax.Array  # (B, d)
    m: jax.Array  # (B, d)


def init_slstm(cfg, rng, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    f = int(cfg.slstm_ff_expand * d)
    ks = jax.random.split(rng, 4)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, dtype),   # z, i, f, o pre-acts
        "r": dense_init(ks[1], dh, 4 * dh, dtype, shape=(H, dh, 4 * dh)),
        "b": jnp.tile(jnp.concatenate(
            [jnp.zeros((d,)), jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
             jnp.zeros((d,))]), (1,)).astype(dtype),
        "norm": init_rmsnorm(d, dtype),
        "ff_gate": dense_init(ks[2], d, f, dtype),
        "ff_down": dense_init(ks[3], f, d, dtype),
    }


def slstm_cache_spec(cfg, batch: int, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMCache(c=z, n=z, h=z, m=z - 1e30)


def _slstm_step(cfg, params, state, wx):
    """wx: precomputed input projection (B, 4d)."""
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    c, n, h, m = state
    B = h.shape[0]
    rh = jnp.einsum("bhi,hij->bhj", h.reshape(B, H, dh).astype(jnp.float32),
                    params["r"].astype(jnp.float32)).reshape(B, 4 * d)
    pre = wx.astype(jnp.float32) + rh + params["b"].astype(jnp.float32)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    lf = _logsig(ft)
    m_new = jnp.maximum(lf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(lf + m - m_new)
    c = f_p * c + i_p * jnp.tanh(zt)
    n = f_p * n + i_p
    h_new = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
    return SLSTMCache(c, n, h_new, m_new), h_new


def apply_slstm(cfg, params, x, *, mode, cache=None):
    B, S, d = x.shape
    wx = x @ params["w_in"]  # (B,S,4d)
    if mode == "decode":
        state, h = _slstm_step(cfg, params, cache, wx[:, 0])
        hs = h[:, None]
        new_cache = state
    else:
        state0 = SLSTMCache(*(jnp.zeros((B, d), jnp.float32),) * 3,
                            m=jnp.full((B, d), -1e30, jnp.float32))

        def step(carry, wxt):
            return _slstm_step(cfg, params, carry, wxt)

        state, hs = jax.lax.scan(step, state0, wx.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)
        new_cache = state if mode == "prefill" else None
    hs = rmsnorm(params["norm"], hs.astype(x.dtype), cfg.norm_eps)
    out = (jax.nn.gelu(hs @ params["ff_gate"], approximate=True)
           @ params["ff_down"])
    return out, new_cache
