"""DeepSeek-V3 671B [arXiv:2412.19437].

61L d_model=7168 128H d_ff(dense)=18432 moe_d_ff=2048 vocab=129280.
MLA (q_lora 1536, kv_lora 512, qk nope/rope 128/64, v 128); MoE with 1
shared + 256 routed experts, top-8; first 3 layers dense; MTP head.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,  # MLA: logical kv per head from shared latent
        d_ff=18432,        # dense-layer FFN width
        moe_d_ff=2048,     # per-routed-expert width
        vocab_size=129_280,
        prefix=tuple(LayerSpec(kind="attn", ffn="dense") for _ in range(3)),
        pattern=(LayerSpec(kind="attn", ffn="moe"),),
        num_repeats=58,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        head_dim=192,  # qk_nope + qk_rope
        num_experts=256,
        experts_per_token=8,
        num_shared_experts=1,
        mtp=True,
        tie_embeddings=False,
        rope_theta=10_000.0,
    )
)
