"""SeamlessM4T-medium text/audio backbone [arXiv:2308.11596].

12L d_model=1024 16H (GQA kv=16 == MHA) d_ff=4096 vocab=256206, enc-dec.
The speech frontend (mel filterbank + w2v-BERT conv feature extractor) is a
stub per the carve-out: ``input_specs`` supplies frame embeddings of shape
(B, frames, d_model); we implement the 12-layer text encoder consuming them
and the 12-layer decoder with cross-attention.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="seamless-m4t-medium",
        family="audio",
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256_206,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        num_repeats=12,
        encoder_layers=12,
        frontend="audio",
        frontend_tokens=1024,  # ~20s of speech at 50 frames/s
        rope_theta=10_000.0,
        tie_embeddings=True,
        act="relu",
        gated_ffn=False,
        scale_embed=True,
    )
)
