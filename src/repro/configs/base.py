"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig`` registered under its
public id (``--arch <id>``).  A config fully determines:

  * the parameter pytree (via ``repro.models.build``),
  * the layer pattern (scan-friendly repeating unit + optional prefix),
  * the modality frontend stub (audio / vision embeddings per the carve-out),
  * which input shapes apply (``long_500k`` only for sub-quadratic archs,
    decode only for archs with a decoder).

Reduced variants for CPU smoke tests come from ``cfg.reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# ``kind``      : 'attn' | 'mamba' | 'mlstm' | 'slstm'
# ``ffn``       : 'dense' | 'moe' | 'none'
# ``window``    : None (global) or int (sliding window, e.g. gemma2 local)


@dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"
    ffn: str = "dense"
    window: Optional[int] = None

    def __post_init__(self):
        assert self.kind in ("attn", "mamba", "mlstm", "slstm"), self.kind
        assert self.ffn in ("dense", "moe", "none"), self.ffn


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # decoder stack: ``prefix`` layers (unrolled) then ``pattern`` repeated
    # ``num_repeats`` times via lax.scan over stacked params.
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    num_repeats: int = 1
    prefix: Tuple[LayerSpec, ...] = ()

    # attention details
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None

    # MLA (DeepSeek-V3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # multi-token prediction (DeepSeek-V3): extra depth-1 MTP head
    mtp: bool = False

    # SSM (Mamba-1)
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2

    # xLSTM
    mlstm_expand: int = 2
    slstm_ff_expand: float = 1.3334

    # encoder-decoder (Seamless)
    encoder_layers: int = 0

    # modality frontend stub: 'audio' | 'vision' | None.  Frontends supply
    # precomputed embeddings via input_specs(); we implement the backbone.
    frontend: Optional[str] = None
    frontend_tokens: int = 256  # patches / frames in the stub prefix

    tie_embeddings: bool = True
    act: str = "silu"
    gated_ffn: bool = True  # SwiGLU/GeGLU vs plain MLP
    norm_eps: float = 1e-6
    # gemma-style extra post-norms around attn/ffn and sqrt(d) embed scaling
    post_norms: bool = False
    scale_embed: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.pattern) * self.num_repeats

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if decode over a 500k context is not full-attention-bound.

        SSM/hybrid archs qualify; dense archs qualify only when every
        attention layer in the repeating unit that is *not* windowed is a
        minority (gemma2: alternating local/global -- global layers are
        linear-in-S bandwidth at decode, cache is the gate; we run it)."""
        kinds = [l.kind for l in self.prefix + self.pattern]
        if all(k != "attn" for k in kinds):
            return True
        attn = [l for l in self.prefix + self.pattern if l.kind == "attn"]
        windowed = [l for l in attn if l.window is not None]
        non_attn = [l for l in self.prefix + self.pattern if l.kind != "attn"]
        # hybrid (jamba): attention minority
        if len(non_attn) > len(attn):
            return True
        # gemma2-style: at least half the attention layers sliding-window
        return len(windowed) * 2 >= len(attn) and len(windowed) > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path

    def shapes(self) -> Tuple[str, ...]:
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.sub_quadratic:
            out.append("long_500k")
        return tuple(out)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """2-layer, d_model<=512, <=4-expert variant of the same family for
        CPU smoke tests (one pattern repeat, truncated prefix)."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        pattern = tuple(self.pattern[:2]) or (LayerSpec(),)
        repl = {
            "d_model": d_model,
            "num_heads": heads,
            "num_kv_heads": kv,
            "head_dim": min(self.resolved_head_dim, 64),
            "d_ff": min(self.d_ff, 512) if self.d_ff else 0,
            "vocab_size": min(self.vocab_size, 512),
            "pattern": pattern,
            "num_repeats": 1,
            "prefix": tuple(self.prefix[:1]),
            "frontend_tokens": min(self.frontend_tokens, 8),
        }
        if self.num_experts:
            repl.update(
                num_experts=min(self.num_experts, 4),
                experts_per_token=min(self.experts_per_token, 2),
                moe_d_ff=min(self.moe_d_ff or self.d_ff, 128),
            )
        if self.use_mla:
            repl.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                        qk_rope_dim=16, v_head_dim=32, head_dim=48)
        if self.encoder_layers:
            repl.update(encoder_layers=2)
        if self.ssm_state_dim:
            repl.update(ssm_state_dim=8)
        return dataclasses.replace(self, **repl)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, f"duplicate arch {cfg.name}"
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # import side-effect registration
    from repro import configs as _  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    from repro import configs as _  # noqa: F401

    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
