"""Config registry: importing this package registers every assigned arch."""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    InputShape,
    INPUT_SHAPES,
    LayerSpec,
    get_config,
    list_configs,
    register,
)

# one module per assigned architecture (registration side effects)
from repro.configs import (  # noqa: F401
    seamless_m4t_medium,
    gemma2_9b,
    deepseek_v3_671b,
    qwen2_72b,
    llama3_2_3b,
    internvl2_26b,
    granite_moe_3b_a800m,
    jamba_v0_1_52b,
    phi3_medium_14b,
    xlstm_125m,
    paper_models,
)

ALL_ARCHS = tuple(sorted(list_configs()))

# typed run configs (imported late: run.py defers its repro.core imports
# to method bodies, so this adds no import-time weight or cycles)
from repro.configs.run import RunSpec, ServeSpec  # noqa: E402,F401
from repro.configs.specs import (  # noqa: E402,F401
    ParsedSpec,
    SpecError,
    parse_spec,
)
