"""The paper's own primary ML models (FedDeper, AAAI-22, Experiment Setup):

* MLP: 2 hidden layers (512, 256)
* CNN/MNIST: conv 32,64 (3x3) + fc 1024, 512
* CNN/CIFAR: conv 64,128 (5x5) + fc 1024, 512, 256

These are *classifier* configs used by the simulation regime (paper
reproduction); they are dataclasses separate from ArchConfig since they are
not sequence models.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ClassifierConfig:
    name: str
    kind: str  # 'mlp' | 'cnn'
    input_shape: Tuple[int, ...]  # (H, W, C) or (D,)
    num_classes: int
    hidden: Tuple[int, ...] = ()
    conv_channels: Tuple[int, ...] = ()
    kernel_size: int = 3


MLP_MNIST = ClassifierConfig(
    name="mlp-mnist", kind="mlp", input_shape=(784,), num_classes=10,
    hidden=(512, 256))

MLP_CIFAR = ClassifierConfig(
    name="mlp-cifar", kind="mlp", input_shape=(3072,), num_classes=10,
    hidden=(512, 256))

CNN_MNIST = ClassifierConfig(
    name="cnn-mnist", kind="cnn", input_shape=(28, 28, 1), num_classes=10,
    conv_channels=(32, 64), kernel_size=3, hidden=(1024, 512))

CNN_CIFAR = ClassifierConfig(
    name="cnn-cifar", kind="cnn", input_shape=(32, 32, 3), num_classes=10,
    conv_channels=(64, 128), kernel_size=5, hidden=(1024, 512, 256))

PAPER_MODELS = {
    c.name: c for c in (MLP_MNIST, MLP_CIFAR, CNN_MNIST, CNN_CIFAR)
}
