"""Qwen2-72B [arXiv:2407.10671].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, QKV bias.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="qwen2-72b",
        family="dense",
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=29568,
        vocab_size=152_064,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        num_repeats=80,
        qkv_bias=True,
        tie_embeddings=False,
        rope_theta=1_000_000.0,
    )
)
