"""One tokenizer for the CLI string mini-languages.

Four flags grew four hand-rolled colon/comma parsers with four error
styles: ``--store virtual:shard:DIR`` (core/store.py), ``--compress
topk:0.25`` (comm/compressors.py), ``--faults drop:P,mode:M,...``
(faults/inject.py) and ``--robust bucket:4,inner:trimmed``
(robust/reducers.py).  The *grammars* are deliberately different -- each
factory owns its vocabulary and value types -- but the lexical shape is
shared: a comma-separated token list where the first token may be a
``head[:arg[:arg]]`` form and the rest are ``key:value`` pairs.

``parse_spec`` is that shared shape.  It splits, validates head / arity /
key vocabulary, and raises uniform errors:

  * unknown head  -> ``--flag: unknown MODE 'tok' (want a|b|c)``
  * bad arity     -> ``--flag: HEAD takes no parameter`` /
                     ``takes at most N parameters``
  * not key:value -> ``--flag: token 'tok': want key:value``
  * unknown key   -> ``--flag: unknown key 'k' (want a|b|c)``

Values come back as strings; casting and range checks stay in the
factories (FaultConfig / RobustConfig / TopK post-inits), which is where
the domain errors ("frac must be in [0, 0.5)") already live and are
tested.  ``head_label`` keeps each flag's historical vocabulary word in
the message ("mode" for --robust, "compressor" for --compress) so the
pinned error-message tests keep matching.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union


class SpecError(ValueError):
    """A malformed CLI spec string (subclass of ValueError so existing
    ``pytest.raises(ValueError)`` pins keep holding)."""


@dataclass(frozen=True)
class ParsedSpec:
    """Lexed spec: ``head`` (None for headless grammars), the head's
    positional ``args``, and the remaining ``key:value`` tokens in
    source order (duplicates preserved -- last-wins is a factory
    policy, not a lexer one)."""

    head: Optional[str]
    args: Tuple[str, ...]
    kv: Tuple[Tuple[str, str], ...]


def _fmt_vocab(words: Sequence[str]) -> str:
    return "|".join(words)


def parse_spec(spec: str, *, flag: str,
               heads: Optional[Sequence[str]] = None,
               arity: Optional[Mapping[str, Tuple[int, int]]] = None,
               greedy: Sequence[str] = (),
               keys: Union[Sequence[str],
                           Mapping[str, Sequence[str]], None] = None,
               head_label: str = "token",
               head_hint: str = "",
               key_hint: str = "") -> ParsedSpec:
    """Lex one CLI spec string.

    ``heads``      -- allowed first-token heads; ``None`` = headless
                      grammar (every comma token is ``key:value``).
    ``arity``      -- per-head ``(min, max)`` positional-arg counts
                      (missing head -> ``(0, 0)``).
    ``greedy``     -- heads whose LAST positional swallows any further
                      colons (``virtual:shard:/tmp/a:b`` keeps the dir
                      intact).
    ``keys``       -- allowed ``key:value`` vocabulary: one sequence for
                      every head, or a per-head mapping; ``None`` = no
                      kv tokens accepted.
    ``head_label`` -- the flag's word for its head in errors ("mode",
                      "compressor", ...).
    ``head_hint`` / ``key_hint`` -- extra text appended to the unknown-
                      head / unknown-key errors (the --faults error
                      enumerates the corrupt modes through this).
    """
    toks = [t.strip() for t in spec.split(",")]
    toks = [t for t in toks if t]
    if not toks:
        raise SpecError(f"{flag}: empty spec {spec!r}")

    head = None
    args: Tuple[str, ...] = ()
    rest = toks
    if heads is not None:
        first = toks[0]
        head = first.split(":", 1)[0].strip()
        if head not in heads:
            hint = f" {head_hint}" if head_hint else ""
            raise SpecError(
                f"{flag}: unknown {head_label} {head!r} "
                f"(want {_fmt_vocab(heads)}){hint}")
        lo, hi = (arity or {}).get(head, (0, 0))
        parts = first.split(":", hi) if head in greedy \
            else first.split(":")
        args = tuple(p.strip() if head not in greedy else p
                     for p in parts[1:])
        if len(args) > hi:
            what = "no parameter" if hi == 0 \
                else f"at most {hi} parameter{'s' if hi > 1 else ''}"
            raise SpecError(
                f"{flag}: {head} takes {what}, "
                f"got {':'.join(args)!r}")
        if len(args) < lo:
            raise SpecError(
                f"{flag}: {head} needs at least {lo} "
                f"parameter{'s' if lo > 1 else ''} in {spec!r}")
        rest = toks[1:]

    allowed = keys
    if isinstance(keys, Mapping):
        allowed = keys.get(head, ())
    kv = []
    for tok in rest:
        if ":" not in tok:
            hint = f" ({key_hint})" if key_hint else ""
            raise SpecError(
                f"{flag}: token {tok!r}: want key:value{hint}")
        k, v = tok.split(":", 1)
        k = k.strip()
        if allowed is None or k not in allowed:
            hint = f"; {key_hint}" if key_hint else ""
            want = _fmt_vocab(allowed) if allowed else "no keys here"
            raise SpecError(
                f"{flag}: unknown key {k!r} (want {want}{hint})")
        kv.append((k, v.strip()))
    return ParsedSpec(head=head, args=args, kv=tuple(kv))


def cast_value(flag: str, key: str, value: str, cast) -> object:
    """Cast one spec value, rewriting the bare ``float('x')`` error into
    the uniform spec-error shape."""
    try:
        return cast(value)
    except (TypeError, ValueError):
        raise SpecError(
            f"{flag}: {key} value {value!r} is not a valid "
            f"{cast.__name__}") from None
