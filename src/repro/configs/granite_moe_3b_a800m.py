"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite/granite-3.0-*-base family].

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 40 experts top-8 (every layer MoE, no shared expert).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=512,
        moe_d_ff=512,
        vocab_size=49_155,
        pattern=(LayerSpec(kind="attn", ffn="moe"),),
        num_repeats=32,
        num_experts=40,
        experts_per_token=8,
        tie_embeddings=True,
        rope_theta=10_000.0,
    )
)
