"""Phi-3-medium 14B [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352, RoPE + SwiGLU.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="phi3-medium-14b",
        family="dense",
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        d_ff=17920,
        vocab_size=100_352,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        num_repeats=40,
        tie_embeddings=False,
        rope_theta=10_000.0,
    )
)
