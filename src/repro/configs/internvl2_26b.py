"""InternVL2-26B language backbone (InternLM2-20B) [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The InternViT-6B
vision encoder + MLP projector are a stub per the carve-out: ``input_specs``
supplies projected patch embeddings (B, patches, d_model) prepended to the
text sequence; we implement the language transformer.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="internvl2-26b",
        family="vlm",
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92_553,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        num_repeats=48,
        frontend="vision",
        frontend_tokens=256,  # 448x448 image -> 256 tokens after pixel-shuffle
        tie_embeddings=False,
        rope_theta=1_000_000.0,
    )
)
