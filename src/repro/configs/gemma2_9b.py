"""Gemma-2 9B [arXiv:2408.00118].

42L d_model=3584 16H (GQA kv=8) head_dim=256 d_ff=14336 vocab=256000.
Alternating local (sliding window 4096) / global attention, attn logit
softcap 50, final logit softcap 30, extra post-norms (gemma2 style).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="gemma2-9b",
        family="dense",
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        pattern=(
            LayerSpec(kind="attn", ffn="dense", window=4096),  # local
            LayerSpec(kind="attn", ffn="dense", window=None),  # global
        ),
        num_repeats=21,
        attn_softcap=50.0,
        logit_softcap=30.0,
        post_norms=True,
        scale_embed=True,
        act="gelu",
        tie_embeddings=True,
        rope_theta=10_000.0,
    )
)
