"""Jamba-v0.1 52B [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Hybrid: each
8-layer Jamba block has 1 attention layer + 7 Mamba layers (1:7), and MoE
(16 experts, top-2) replaces the MLP on every other layer.
"""
from repro.configs.base import ArchConfig, LayerSpec, register


def _jamba_block():
    """One 8-layer Jamba block: attn at index 4 (as released), MoE on odd."""
    layers = []
    for idx in range(8):
        kind = "attn" if idx == 4 else "mamba"
        ffn = "moe" if idx % 2 == 1 else "dense"
        layers.append(LayerSpec(kind=kind, ffn=ffn))
    return tuple(layers)


CONFIG = register(
    ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        moe_d_ff=14336,
        vocab_size=65_536,
        pattern=_jamba_block(),
        num_repeats=4,
        num_experts=16,
        experts_per_token=2,
        ssm_state_dim=16,
        ssm_conv_dim=4,
        ssm_expand=2,
        tie_embeddings=False,
        rope_theta=10_000.0,
    )
)
