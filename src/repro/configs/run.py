"""Typed, validated run configs for the two entry points.

``RunSpec`` covers the FULL training surface of ``launch/train.py`` --
every CLI flag is a field with the same name and default -- and
``ServeSpec`` is its serving-tier sibling for ``launch/serve.py``.
Both share one idiom:

  * ``from_args(argv)``  -- parse the CLI.  ``--config run.json`` loads
    a JSON spec first and explicit flags override it field by field
    (``argparse.SUPPRESS`` keeps untyped flags from clobbering the
    file's values with defaults).
  * ``from_json(path)`` / ``to_json()`` -- the same fields as a JSON
    object; unknown keys fail fast.
  * ``validate()``       -- cross-field constraints.  For RunSpec these
    are the historical ``launch/train.py`` guard rails (``--robust``
    needs a placement, ``--bandwidth`` needs the async regime, ...),
    raised as ``SystemExit`` with the same messages so CLI behaviour is
    unchanged.
  * ``to_meta()``        -- the canonical config metadata stamped into
    checkpoints and re-validated on resume.  Canonicalization goes
    through the real factories (``make_compressor`` /`` make_faults`` /
    ``make_layout`` / ``make_robust``), so two specs match iff the
    factories would build the same thing -- the ad-hoc per-key dicts
    the drivers used to assemble are gone.

The argparse surface lives HERE (``RunSpec.parser()``), single-sourced:
``launch/train.py`` just calls ``RunSpec.from_args``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from dataclasses import dataclass, fields
from typing import Any, Dict, Optional, Tuple


def _coerce(f: dataclasses.Field, v: Any) -> Any:
    """JSON -> field coercion: ints may stand in for floats, everything
    else must already be the right shape (bools/ints/strings/None)."""
    if v is None:
        return None
    if f.type in ("float", "Optional[float]") and isinstance(v, int) \
            and not isinstance(v, bool):
        return float(v)
    return v


class _SpecBase:
    """Shared from_args/from_json/to_json plumbing.  Subclasses supply
    ``parser(suppress)`` returning an argparse parser whose dests match
    the dataclass fields (plus the ``--config`` meta-flag)."""

    @classmethod
    def from_json(cls, path: str) -> "_SpecBase":
        with open(path) as f:
            data = json.load(f)
        return cls.from_dict(data, where=path)

    @classmethod
    def from_dict(cls, data: Dict[str, Any],
                  where: str = "<dict>") -> "_SpecBase":
        names = {f.name: f for f in fields(cls)}
        unknown = sorted(set(data) - set(names))
        if unknown:
            raise SystemExit(
                f"{cls.__name__} {where}: unknown field(s) "
                f"{', '.join(unknown)} (want a subset of "
                f"{', '.join(sorted(names))})")
        return cls(**{k: _coerce(names[k], v) for k, v in data.items()})

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.to_dict(), indent=2, sort_keys=True)
        if path:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    @classmethod
    def from_args(cls, argv=None) -> "_SpecBase":
        ns = cls.parser(suppress=True).parse_args(argv)
        over = dict(vars(ns))
        config = over.pop("config", None)
        base = cls.from_json(config) if config else cls()
        return dataclasses.replace(base, **over)

    def replace(self, **kw) -> "_SpecBase":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunSpec(_SpecBase):
    """The full training surface: one field per ``launch/train.py``
    flag, same names, same defaults."""

    arch: str = "llama3.2-3b"
    reduced: bool = False
    strategy: str = "feddeper"
    clients: int = 2
    tau: int = 4
    rounds: int = 10
    batch: int = 2
    seq: int = 128
    eta: float = 0.05
    rho: float = 0.01
    lam: float = 0.5
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    regime: str = "datacenter"
    placement: Optional[str] = None
    sampled: Optional[int] = None
    block_rounds: Optional[int] = None
    concurrent: int = 4
    buffer: int = 2
    alpha: float = 0.5
    delay: float = 5.0
    delay_dist: str = "lognormal"
    delay_sigma: float = 1.0
    per_client: int = 64
    store: str = "dense"
    compress: str = "none"
    bandwidth: float = 0.0
    faults: str = "none"
    robust: str = "none"
    clip_norm: float = 0.0
    max_retries: int = 3

    # -- construction -------------------------------------------------

    @classmethod
    def parser(cls, suppress: bool = False) -> argparse.ArgumentParser:
        from repro.core import STRATEGIES
        from repro.faults import CORRUPT_MODES
        from repro.robust import ROBUST_MODES
        d = cls()

        def dflt(v):
            # argparse ignores argument_default once an explicit
            # default= is given, so every argument routes through this:
            # suppress mode leaves unpassed flags OUT of the namespace
            # (a --config JSON base must not be clobbered by defaults)
            return argparse.SUPPRESS if suppress else v

        ap = argparse.ArgumentParser()
        ap.add_argument("--config", default=dflt(None),
                        help="JSON RunSpec to start from; explicit "
                             "flags override its fields")
        ap.add_argument("--arch", default=dflt(d.arch))
        ap.add_argument("--reduced", action="store_true",
                        default=dflt(False),
                        help="2-layer smoke variant (CPU)")
        ap.add_argument("--strategy", default=dflt(d.strategy),
                        choices=sorted(STRATEGIES))
        ap.add_argument("--clients", type=int, default=dflt(d.clients))
        ap.add_argument("--tau", type=int, default=dflt(d.tau))
        ap.add_argument("--rounds", type=int, default=dflt(d.rounds))
        ap.add_argument("--batch", type=int, default=dflt(d.batch),
                        help="per-client b")
        ap.add_argument("--seq", type=int, default=dflt(d.seq))
        ap.add_argument("--eta", type=float, default=dflt(d.eta))
        ap.add_argument("--rho", type=float, default=dflt(d.rho))
        ap.add_argument("--lam", type=float, default=dflt(d.lam))
        ap.add_argument("--seed", type=int, default=dflt(d.seed))
        ap.add_argument("--ckpt-dir", default=dflt(d.ckpt_dir))
        ap.add_argument("--ckpt-every", type=int, default=dflt(d.ckpt_every))
        # buffered-async regime (core/async_rounds.py)
        ap.add_argument("--regime", default=dflt(d.regime),
                        choices=("datacenter", "async"))
        # cohort-engine placement (core/engine.py); None = legacy
        # fixed-cohort datacenter step
        ap.add_argument("--placement", default=dflt(d.placement),
                        choices=("vmap", "mesh"),
                        help="cohort placement (core/engine.py): 'vmap' "
                             "single-device, 'mesh' cohort + stores over "
                             "the client axis of all local devices.  "
                             "Sync regime: routes through the cohort "
                             "engine instead of the legacy fixed-cohort "
                             "step.  --regime async: 'mesh' pads "
                             "dispatch cohorts onto the client axis and "
                             "lowers the staleness-weighted aggregate "
                             "to one psum")
        ap.add_argument("--sampled", type=int, default=dflt(d.sampled),
                        help="engine placement: clients sampled per "
                             "round (default: all; mesh needs it "
                             "divisible by the client-axis size)")
        ap.add_argument("--block-rounds", type=int,
                        default=dflt(d.block_rounds),
                        help="engine placement: rounds per scan-compiled "
                             "block (one jitted lax.scan, one host sync "
                             "and one donation handoff per block); eval "
                             "and checkpoints fire at block boundaries")
        ap.add_argument("--concurrent", type=int, default=dflt(d.concurrent),
                        help="async: clients training simultaneously")
        ap.add_argument("--buffer", type=int, default=dflt(d.buffer),
                        help="async: uploads per aggregation")
        ap.add_argument("--alpha", type=float, default=dflt(d.alpha),
                        help="async: staleness discount exponent")
        ap.add_argument("--delay", type=float, default=dflt(d.delay),
                        help="async: mean client delay (0 = no "
                             "stragglers)")
        ap.add_argument("--delay-dist", default=dflt(d.delay_dist),
                        choices=("constant", "uniform", "lognormal"))
        ap.add_argument("--delay-sigma", type=float,
                        default=dflt(d.delay_sigma),
                        help="async: lognormal delay shape (straggler "
                             "heaviness); only used with "
                             "--delay-dist lognormal")
        ap.add_argument("--per-client", type=int, default=dflt(d.per_client),
                        help="async/--placement: LM sequences "
                             "materialized per client")
        # client-store layout (repro.core.store)
        ap.add_argument("--store", default=dflt(d.store),
                        help="client-store layout: dense | virtual[:host|"
                             ":recon|:shard[:DIR]] -- 'dense' keeps full "
                             "(n_clients, ...) stores on device; "
                             "'virtual' keeps only the sampled cohort's "
                             "rows on device against a host / "
                             "reconstructible / checkpoint-shard backing "
                             "tier (O(cohort) device memory, "
                             "bitwise-identical trajectory)")
        # uplink compression (repro.comm)
        ap.add_argument("--compress", default=dflt(d.compress),
                        help="uplink compressor: none | identity | q8 | "
                             "fp8 | topk:R (keep-ratio R in [0,1], e.g. "
                             "topk:0.1); 'none' is trace-identical to "
                             "the pre-comm engine")
        ap.add_argument("--bandwidth", type=float, default=dflt(d.bandwidth),
                        help="async: uplink bytes per simulated-time "
                             "unit; deliveries pay payload_bytes/"
                             "bandwidth extra (0 = no bandwidth model)")
        # fault injection + screening (repro.faults)
        ap.add_argument("--faults", default=dflt(d.faults),
                        help="fault spec: none | drop:P,corrupt:P[,"
                             "mode:M,scale:S,bitflip:F,z:Z,deadline:T] "
                             "-- per-client per-round dropouts / "
                             "corrupted uploads (M in "
                             f"{'|'.join(CORRUPT_MODES)}; the stealth "
                             "modes alie/collude/ipflip also take the "
                             "shorthand alie:P etc. and strength z:Z), "
                             "all derived deterministically from the "
                             "round rng; deadline:T is async-only "
                             "(dispatches finishing after T sim-time "
                             "units never deliver)")
        ap.add_argument("--robust", default=dflt(d.robust),
                        help="Byzantine-robust aggregation "
                             "(repro.robust): none | "
                             f"{' | '.join(ROBUST_MODES)} -- trimmed:F "
                             "per-coordinate trimmed mean (trim "
                             "fraction F per tail), median, krum:F "
                             "keep-closest-to-the-pack filtering, "
                             "bucket:B[,inner:median|trimmed] bucketed "
                             "robust mean (B buckets ride the round's "
                             "single psum); 'none' is trace-identical "
                             "to the plain mean (engine placements "
                             "only)")
        ap.add_argument("--clip-norm", type=float, default=dflt(d.clip_norm),
                        help="server-side upload-norm clip: uploads "
                             "with l2 norm above C are scaled down "
                             "inside the aggregation weights (0 = off; "
                             "engine placements only)")
        ap.add_argument("--max-retries", type=int, default=dflt(d.max_retries),
                        help="crash-safe recovery: consecutive rollback+"
                             "reseed retries of a round/block that left "
                             "the global model non-finite before giving "
                             "up")
        return ap

    # -- validation ---------------------------------------------------

    def validate(self) -> "RunSpec":
        """Cross-field guard rails, verbatim from the historical
        ``launch/train.py`` main(); ``SystemExit`` keeps CLI behaviour
        (message on stderr, nonzero exit) identical.  Field-level
        vocabulary is re-checked too so ``from_json`` specs get the
        same errors argparse ``choices`` would give the CLI."""
        from repro.core import STRATEGIES
        if self.strategy not in STRATEGIES:
            raise SystemExit(
                f"unknown strategy {self.strategy!r} "
                f"(want {'|'.join(sorted(STRATEGIES))})")
        if self.regime not in ("datacenter", "async"):
            raise SystemExit(
                f"unknown regime {self.regime!r} (want datacenter|async)")
        if self.placement not in (None, "vmap", "mesh"):
            raise SystemExit(
                f"unknown placement {self.placement!r} (want vmap|mesh)")
        if self.delay_dist not in ("constant", "uniform", "lognormal"):
            raise SystemExit(
                f"unknown delay_dist {self.delay_dist!r} "
                "(want constant|uniform|lognormal)")
        if self.block_rounds is not None and self.block_rounds < 1:
            raise SystemExit("--block-rounds must be >= 1")
        if self.block_rounds and not self.placement:
            raise SystemExit(
                "--block-rounds drives the cohort engine: pass "
                "--placement {vmap,mesh} (the async regime's sim-time "
                "advance is host-side and cannot be scanned)")
        if self.compress != "none" and self.regime != "async" \
                and not self.placement:
            raise SystemExit(
                "--compress rides the comm-aware paths: pass "
                "--placement {vmap,mesh} or --regime async (the legacy "
                "fixed-cohort datacenter step has no uplink seam)")
        if self.store != "dense" and self.regime != "async" \
                and not self.placement:
            raise SystemExit(
                "--store virtual rides the cohort-engine store seam: "
                "pass --placement {vmap,mesh} or --regime async (the "
                "legacy fixed-cohort datacenter step holds its client "
                "store inline)")
        if self.bandwidth and self.regime != "async":
            raise SystemExit(
                "--bandwidth prices the simulated async uplink queue: "
                "pass --regime async (the synchronous regimes have no "
                "simulated clock; previously the flag was silently "
                "ignored)")
        if (self.faults != "none" or self.clip_norm) \
                and self.regime != "async" and not self.placement:
            raise SystemExit(
                "--faults/--clip-norm ride the fault-aware paths: pass "
                "--placement {vmap,mesh} or --regime async (the legacy "
                "fixed-cohort datacenter step has no screening seam)")
        if self.robust != "none" and self.regime == "async":
            raise SystemExit(
                "--robust reduces one synchronous cohort's upload "
                "stack: the async regime's staleness-discounted buffer "
                "aggregates incrementally and has no robust seam (run "
                "--regime datacenter)")
        if self.robust != "none" and not self.placement:
            raise SystemExit(
                "--robust rides the cohort engine's aggregate seam: "
                "pass --placement {vmap,mesh} (the legacy fixed-cohort "
                "datacenter step has no mean_fn seam)")
        if self.clip_norm and self.regime == "async":
            raise SystemExit(
                "--clip-norm screens synchronous cohort uploads inside "
                "the weighted mean: the async regime's staleness-"
                "discounted buffer has no per-lane weight vector (only "
                "--faults deadline:T applies there)")
        return self

    # -- derived objects ----------------------------------------------

    def make_strategy(self):
        from repro.core import STRATEGIES
        kw = dict(eta=self.eta)
        if self.strategy == "feddeper":
            kw.update(rho=self.rho, lam=self.lam)
        return STRATEGIES[self.strategy](**kw)

    def arch_config(self):
        from repro.configs import get_config
        cfg = get_config(self.arch)
        return cfg.reduced() if self.reduced else cfg

    def to_meta(self) -> Dict[str, str]:
        """Canonical checkpoint metadata: resume re-validates these four
        keys against the resuming run's spec.  Canonical form comes from
        the factories themselves (``FaultConfig.spec`` etc.), so
        ``faults='drop:0.2,corrupt:0'`` and ``faults='drop:0.2'`` agree."""
        from repro.comm import make_compressor
        from repro.core import make_layout
        from repro.faults import make_faults
        from repro.robust import make_robust
        comp = make_compressor(self.compress)
        flt = make_faults(self.faults, clip_norm=self.clip_norm)
        robust = make_robust(self.robust)
        return {"compress": comp.name if comp else "none",
                "faults": flt.spec if flt else "none",
                "store": make_layout(self.store).spec,
                "robust": robust.spec if robust else "none"}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServeSpec(_SpecBase):
    """The serving-tier surface (``launch/serve.py`` / ``repro.serve``).

    ``weights`` is a WeightSource spec (serve/weights.py):
    ``init[:SEED]`` | ``ckpt:DIR`` | ``q8[:SRC]`` | ``fp8[:SRC]``;
    ``ckpt_dir`` is CLI sugar that rewrites ``init`` -> ``ckpt:DIR``
    so ``--ckpt-dir`` from a training run drops straight in."""

    arch: str = "llama3.2-3b"
    reduced: bool = False
    weights: str = "init"
    ckpt_dir: Optional[str] = None
    slots: int = 4                 # concurrent decode slots (batch rows)
    max_len: int = 128             # KV-cache capacity per slot
    block_tokens: int = 16         # tokens per jitted decode block
    prompt_len: int = 16           # batch mode: uniform prompt length
    gen_tokens: int = 32           # tokens generated per request
    seed: int = 0
    # request simulator (serve/simulator.py)
    simulate: bool = False
    requests: int = 8
    prompt_lens: str = "4,8,12,16"  # simulator: mixed prompt lengths
    delay: float = 0.0             # mean inter-arrival time (sim units)
    delay_dist: str = "lognormal"
    delay_sigma: float = 1.0
    time_unit: float = 0.0         # wall seconds per sim-time unit

    @classmethod
    def parser(cls, suppress: bool = False) -> argparse.ArgumentParser:
        d = cls()

        def dflt(v):
            # argparse ignores argument_default once an explicit
            # default= is given, so every argument routes through this:
            # suppress mode leaves unpassed flags OUT of the namespace
            # (a --config JSON base must not be clobbered by defaults)
            return argparse.SUPPRESS if suppress else v

        ap = argparse.ArgumentParser()
        ap.add_argument("--config", default=dflt(None),
                        help="JSON ServeSpec to start from; explicit "
                             "flags override its fields")
        ap.add_argument("--arch", default=dflt(d.arch))
        ap.add_argument("--reduced", action="store_true",
                        default=dflt(False))
        ap.add_argument("--weights", default=dflt(d.weights),
                        help="weight source: init[:SEED] | ckpt:DIR | "
                             "q8[:SRC] | fp8[:SRC] (SRC defaults to "
                             "init; q8:ckpt:DIR serves an int8-packed "
                             "checkpoint)")
        ap.add_argument("--ckpt-dir", default=dflt(d.ckpt_dir),
                        help="sugar for --weights ckpt:DIR: load the "
                             "global model from a launch/train.py "
                             "checkpoint directory")
        ap.add_argument("--slots", type=int, default=dflt(d.slots),
                        help="concurrent decode slots (the batch)")
        ap.add_argument("--max-len", type=int, default=dflt(d.max_len),
                        help="KV-cache rows per slot")
        ap.add_argument("--block-tokens", type=int,
                        default=dflt(d.block_tokens),
                        help="tokens per jitted lax.scan decode block "
                             "(one host sync per block)")
        ap.add_argument("--prompt-len", type=int, default=dflt(d.prompt_len))
        ap.add_argument("--gen-tokens", type=int, default=dflt(d.gen_tokens))
        ap.add_argument("--seed", type=int, default=dflt(d.seed))
        ap.add_argument("--simulate", action="store_true",
                        default=dflt(False),
                        help="run the continuous-batching request "
                             "simulator instead of one uniform batch")
        ap.add_argument("--requests", type=int, default=dflt(d.requests))
        ap.add_argument("--prompt-lens", default=dflt(d.prompt_lens),
                        help="simulator: comma list of prompt lengths "
                             "cycled over the requests")
        ap.add_argument("--delay", type=float, default=dflt(d.delay),
                        help="simulator: mean request inter-arrival "
                             "time in sim units (0 = all at t0)")
        ap.add_argument("--delay-dist", default=dflt(d.delay_dist),
                        choices=("constant", "uniform", "lognormal"))
        ap.add_argument("--delay-sigma", type=float,
                        default=dflt(d.delay_sigma))
        ap.add_argument("--time-unit", type=float, default=dflt(d.time_unit),
                        help="wall seconds per sim-time unit (0 = "
                             "arrivals only order the queue)")
        return ap

    def resolve_weights(self) -> str:
        """Apply the ``--ckpt-dir`` sugar: an explicit ``--weights``
        wins; with the default ``init`` a checkpoint dir rewrites the
        source (quantized sugar composes: ``q8`` + ckpt_dir =
        ``q8:ckpt:DIR``)."""
        if not self.ckpt_dir:
            return self.weights
        if self.weights == "init":
            return f"ckpt:{self.ckpt_dir}"
        if self.weights in ("q8", "fp8"):
            return f"{self.weights}:ckpt:{self.ckpt_dir}"
        return self.weights

    def parsed_prompt_lens(self) -> Tuple[int, ...]:
        try:
            lens = tuple(int(t) for t in
                         str(self.prompt_lens).split(",") if t.strip())
        except ValueError:
            raise SystemExit(
                f"--prompt-lens {self.prompt_lens!r}: want a comma "
                "list of ints, e.g. 4,8,12") from None
        if not lens:
            raise SystemExit("--prompt-lens must name at least one "
                             "prompt length")
        return lens

    def validate(self) -> "ServeSpec":
        if self.slots < 1:
            raise SystemExit("--slots must be >= 1")
        if self.block_tokens < 1:
            raise SystemExit("--block-tokens must be >= 1")
        if self.gen_tokens < 1:
            raise SystemExit("--gen-tokens must be >= 1")
        if self.delay_dist not in ("constant", "uniform", "lognormal"):
            raise SystemExit(
                f"unknown delay_dist {self.delay_dist!r} "
                "(want constant|uniform|lognormal)")
        lens = self.parsed_prompt_lens() if self.simulate \
            else (self.prompt_len,)
        worst = max(lens)
        if worst < 1:
            raise SystemExit("prompt lengths must be >= 1")
        if worst + self.gen_tokens > self.max_len:
            raise SystemExit(
                f"--max-len {self.max_len} cannot hold a "
                f"{worst}-token prompt plus {self.gen_tokens} generated "
                f"tokens: raise --max-len to >= "
                f"{worst + self.gen_tokens}")
        if self.requests < 1 and self.simulate:
            raise SystemExit("--requests must be >= 1")
        return self
