"""Llama-3.2 3B [hf:meta-llama/Llama-3.2-1B family, scaled per assignment].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="llama3.2-3b",
        family="dense",
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=128_256,
        pattern=(LayerSpec(kind="attn", ffn="dense"),),
        num_repeats=28,
        tie_embeddings=True,
        rope_theta=500_000.0,
    )
)
