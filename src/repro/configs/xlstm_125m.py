"""xLSTM 125M [arXiv:2405.04517].

12 blocks d_model=768 4H vocab=50304, alternating mLSTM / sLSTM blocks
(d_ff=0: the blocks carry their own up/down projections; the sLSTM block
includes a gated feed-forward of expansion ~4/3 as in the paper).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        name="xlstm-125m",
        family="ssm",
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50_304,
        pattern=(
            LayerSpec(kind="mlstm", ffn="none"),
            LayerSpec(kind="slstm", ffn="none"),
        ),
        num_repeats=6,
        mlstm_expand=2,
        slstm_ff_expand=1.3334,
        tie_embeddings=True,
    )
)
