"""Deterministic client fault injection + server-side upload screening.

The fault model mirrors the comm layer's rng contract: every per-round,
per-client fault draw derives from the round's batch key through a
``fold_in`` salt -- a pure function of an existing key, consuming nothing
from the round stream -- so turning faults on perturbs neither the cohort
sample nor the batch draws, and ``fault_rate=0`` configs trace the exact
no-fault program (``FaultConfig.active`` gates the whole layer out
statically).

Fault classes (ISSUE 7 / DESIGN.md §10):

  * dropouts       -- the client never uploads; its lane is screened to
                      zero weight AND zero value, its client/pms/ef rows
                      revert to their pre-round state.
  * corrupted      -- the upload arrives damaged: non-finite (nan/inf),
    uploads           Byzantine (sign-flip / scale), or bit-flips applied
                      to the compressed WIRE buffer (composing with
                      ``repro.comm``).
  * stealth        -- finite-valued adversarial modes that PASS the
    attacks           screening below and target the mean itself:
                      ``alie`` (small-sigma collusion along one shared
                      direction), ``collude`` (coordinated sign-flip,
                      adaptive to ``clip_norm`` -- it rides the clip
                      boundary), ``ipflip`` (inner-product flip,
                      -z * upload).  Defended by ``repro.robust``.
  * stragglers     -- async-only deadline faults (``deadline``): a
                      dispatch whose simulated finish time exceeds the
                      deadline never delivers (``async_rounds``).

Screening is NOT a second collective: ``screen_upload`` runs inside the
per-client lane (shard-local under the mesh placement), emits a per-lane
weight in [0, 1] -- 0 for dropped/non-finite lanes, a clip scale for
over-norm ones -- and ZEROES the values of every zero-weight lane so a
NaN can never ride the psum (0 * NaN = NaN otherwise).  The engine lowers
the weights into the round's single cross-client psum via
``strategies.LocalWeights`` / ``engine._psum_mean_fn``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

# fold_in salt deriving the fault layer's per-round key from k_batch --
# same contract as engine._COMM_SALT (0xC0111): a pure function of an
# existing key, so fault schedules are deterministic AND adding faults
# never perturbs the cohort/batch/comm streams.
_FAULT_SALT = 0xFA017

# sub-salt WITHIN the 0xFA017 stream for the round's SHARED attack key:
# colluding lanes coordinate through one broadcast key (fold_in-derived,
# so it costs no collective and no draw), while fault_round_keys SPLITS
# the same base key -- fold_in vs split keeps the two derivations
# structurally disjoint (DESIGN.md §10 salt table).
_ATTACK_TAG = 0xA11E

# finite-valued colluding modes: they pass PR 7 screening by design and
# need the shared per-round attack key threaded into the lane
STEALTH_MODES = ("alie", "collude", "ipflip")
CORRUPT_MODES = ("nan", "inf", "signflip", "scale", "bitflip") \
    + STEALTH_MODES

_UINT_OF_SIZE = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


@dataclass(frozen=True)
class FaultConfig:
    """Per-client per-round fault probabilities + screening knobs.

    ``drop``/``corrupt`` are per-client per-round probabilities (a client
    cannot be both: corruption is drawn from the survivors).  ``deadline``
    (simulated time units, async regime only) marks dispatches whose
    finish time exceeds it as timed out.  ``clip_norm`` > 0 enables
    server-side upload-norm clipping (screening, not injection: it is
    applied to every upload, faulty or not)."""

    drop: float = 0.0
    corrupt: float = 0.0
    corrupt_mode: str = "nan"
    corrupt_scale: float = 100.0   # 'scale' mode multiplier
    bitflip_frac: float = 1e-3     # 'bitflip' mode: fraction of elements
    attack_z: float = 1.5          # stealth attack strength (alie/ipflip)
    deadline: float = 0.0          # async straggler deadline (0 = off)
    clip_norm: float = 0.0         # upload L2-norm clip (0 = off)

    def __post_init__(self):
        for f in ("drop", "corrupt"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultConfig.{f}={v} not in [0, 1]")
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(
                f"corrupt_mode {self.corrupt_mode!r} not in "
                f"{'|'.join(CORRUPT_MODES)}")
        if self.deadline < 0 or self.clip_norm < 0:
            raise ValueError("deadline / clip_norm must be >= 0")
        if not 0.0 <= self.bitflip_frac <= 1.0:
            raise ValueError("bitflip_frac must be in [0, 1]")
        if self.attack_z <= 0:
            raise ValueError("attack_z must be > 0")

    @property
    def active(self) -> bool:
        """True when the SYNC fault layer changes the round program.
        ``deadline`` alone is async-only and keeps the sync trace
        untouched; the engine normalizes inactive configs to ``None`` so
        fault_rate=0 is bitwise-equal to the no-fault trace."""
        return self.drop > 0 or self.corrupt > 0 or self.clip_norm > 0

    @property
    def spec(self) -> str:
        """Canonical ``--faults`` spec string (checkpoint metadata: two
        configs match iff their specs match)."""
        d = FaultConfig()
        parts = []
        if self.drop != d.drop:
            parts.append(f"drop:{self.drop:g}")
        if self.corrupt != d.corrupt:
            parts.append(f"corrupt:{self.corrupt:g}")
        if self.corrupt_mode != d.corrupt_mode:
            parts.append(f"mode:{self.corrupt_mode}")
        if self.corrupt_scale != d.corrupt_scale:
            parts.append(f"scale:{self.corrupt_scale:g}")
        if self.bitflip_frac != d.bitflip_frac:
            parts.append(f"bitflip:{self.bitflip_frac:g}")
        if self.attack_z != d.attack_z:
            parts.append(f"z:{self.attack_z:g}")
        if self.deadline != d.deadline:
            parts.append(f"deadline:{self.deadline:g}")
        if self.clip_norm != d.clip_norm:
            parts.append(f"clip:{self.clip_norm:g}")
        return ",".join(parts) if parts else "none"


def make_faults(spec: Optional[str], clip_norm: float = 0.0
                ) -> Optional[FaultConfig]:
    """Parse a ``--faults`` spec ('drop:0.2,corrupt:0.05,mode:nan,
    deadline:3.5,...') into a FaultConfig; 'none'/''/None with no
    clip_norm -> None (the engine's fault-free fast path).

    Stealth sugar: ``collude:F`` == ``corrupt:F,mode:collude`` (same for
    ``alie:F`` / ``ipflip:F``); ``z:VAL`` sets the attack strength."""
    _KEYS = {
        "drop": ("drop", float),
        "corrupt": ("corrupt", float),
        "mode": ("corrupt_mode", str),
        "scale": ("corrupt_scale", float),
        "bitflip": ("bitflip_frac", float),
        "z": ("attack_z", float),
        "deadline": ("deadline", float),
        "clip": ("clip_norm", float),
    }
    kw: Dict[str, Any] = {}
    if spec and spec != "none":
        from repro.configs.specs import cast_value, parse_spec
        p = parse_spec(
            spec, flag="--faults",
            keys=tuple(_KEYS) + STEALTH_MODES,
            key_hint=f"stealth-mode shorthands "
                     f"{'|'.join(STEALTH_MODES)} take alie:P etc.; "
                     f"mode M in {'|'.join(CORRUPT_MODES)}")
        for k, v in p.kv:
            if k in STEALTH_MODES:
                # collude:0.2 == corrupt:0.2,mode:collude
                kw["corrupt"] = cast_value("--faults", k, v, float)
                kw["corrupt_mode"] = k
                continue
            key, cast = _KEYS[k]
            kw[key] = cast_value("--faults", k, v, cast) \
                if cast is float else cast(v)
    if clip_norm:
        kw["clip_norm"] = float(clip_norm)
    if not kw:
        return None
    cfg = FaultConfig(**kw)
    if not cfg.active and cfg.deadline == 0:
        return None
    return cfg


def fault_round_keys(k_batch, m: int) -> jax.Array:
    """Per-cohort-lane fault keys, derived from (not consuming) the
    round's batch key -- one definition for every placement and block
    size, so the fault schedule is a pure function of (seed, round)."""
    return jax.random.split(jax.random.fold_in(k_batch, _FAULT_SALT), m)


def attack_round_key(k_batch) -> jax.Array:
    """The round's SHARED stealth-attack key: every colluding lane
    receives the same key (broadcast operand, zero collectives), so
    their perturbations coordinate without cross-lane traffic.  Derived
    INSIDE the 0xFA017 stream -- ``fold_in(fold_in(k_batch, 0xFA017),
    0xA11E)`` -- while ``fault_round_keys`` SPLITS the same base key, so
    the per-lane and shared streams cannot collide."""
    return jax.random.fold_in(
        jax.random.fold_in(k_batch, _FAULT_SALT), _ATTACK_TAG)


def needs_attack_key(cfg: Optional[FaultConfig]) -> bool:
    """True when the engine must thread the shared attack key into the
    per-client lane (stealth corrupt modes only: the non-stealth traces
    stay byte-identical to pre-stealth builds)."""
    return cfg is not None and cfg.corrupt_mode in STEALTH_MODES


def fault_draws(cfg: FaultConfig, fkey) -> Tuple[jax.Array, jax.Array,
                                                 jax.Array]:
    """One lane's fault draw: ``(dropped, corrupted, k_payload)``.
    Corruption is drawn from the drop survivors (a dropped client has no
    upload to corrupt); ``k_payload`` seeds the payload damage."""
    k_drop, k_cor, k_pay = jax.random.split(fkey, 3)
    dropped = jax.random.uniform(k_drop, ()) < cfg.drop
    corrupted = jnp.logical_and(
        jnp.logical_not(dropped),
        jax.random.uniform(k_cor, ()) < cfg.corrupt)
    return dropped, corrupted, k_pay


def _bitflip_array(t: jax.Array, key, frac: float, gate) -> jax.Array:
    """Flip one random bit in ~``frac`` of ``t``'s elements (when ``gate``
    is true): bitcast to the same-width uint, XOR a random single-bit
    mask on the hit elements, bitcast back.  Models transport-level wire
    damage -- f32 exponent hits produce huge/non-finite values, which is
    the point."""
    nbits = t.dtype.itemsize * 8
    ut = _UINT_OF_SIZE[t.dtype.itemsize]
    k_hit, k_bit = jax.random.split(key)
    hit = jax.random.uniform(k_hit, t.shape) < frac
    bit = jax.random.randint(k_bit, t.shape, 0, nbits, dtype=jnp.int32)
    mask = (jnp.ones((), ut) << bit.astype(ut)).astype(ut)
    raw = jax.lax.bitcast_convert_type(t, ut)
    flipped = jax.lax.bitcast_convert_type(raw ^ mask, t.dtype)
    take = jnp.logical_and(gate, hit)
    return jnp.where(take, flipped, t)


def corrupt_payload(cfg: FaultConfig, upload: Pytree, corrupted,
                    key, akey=None) -> Pytree:
    """Apply the configured non-wire corruption to one lane's (dense,
    decompressed) upload when ``corrupted`` is true.  'bitflip' here is
    the no-compressor fallback (with a compressor the flip targets the
    wire buffer via ``wire_corruptor``).  The stealth modes take the
    round's SHARED ``akey`` (``attack_round_key``): all colluding lanes
    perturb coherently, which is what makes the plain mean crater while
    per-lane noise would average out."""
    mode = cfg.corrupt_mode
    if mode in STEALTH_MODES and akey is None:
        raise ValueError(
            f"stealth corrupt_mode {mode!r} needs the round's shared "
            "attack key: pass akey=attack_round_key(k_batch) (a silent "
            "per-lane fallback would de-coordinate the collusion)")
    if mode == "alie":
        # small-sigma collusion (a-little-is-enough): shift the upload
        # z local-stds along ONE shared Rademacher direction.  Finite,
        # norm-comparable to honest uploads -> passes screening; the
        # coherent shift survives the mean but not a trim/Krum.
        leaves, treedef = jax.tree_util.tree_flatten(upload)
        out = []
        for i, t in enumerate(leaves):
            k_dir = jax.random.fold_in(akey, i)
            d = jnp.where(jax.random.bernoulli(k_dir, 0.5, t.shape),
                          1.0, -1.0)
            tf = t.astype(jnp.float32)
            pert = (tf + cfg.attack_z * jnp.std(tf) * d).astype(t.dtype)
            out.append(jnp.where(corrupted, pert, t))
        return jax.tree_util.tree_unflatten(treedef, out)
    if mode == "collude":
        # coordinated sign-flip: exactly -upload (norm-preserving, so
        # norm screening is blind to it).  When the server clips, the
        # colluders ADAPT: they rescale to ride exactly at the clip
        # boundary -- the maximum admissible poisoned mass.
        if cfg.clip_norm > 0:
            leaves = jax.tree.leaves(upload)
            sq = sum(jnp.sum(jnp.square(t.astype(jnp.float32)))
                     for t in leaves)
            s = cfg.clip_norm * jax.lax.rsqrt(jnp.maximum(sq, 1e-30))
        else:
            s = jnp.asarray(1.0, jnp.float32)
        return jax.tree.map(
            lambda t: jnp.where(
                corrupted, (-s * t.astype(jnp.float32)).astype(t.dtype),
                t), upload)
    if mode == "ipflip":
        # inner-product flip (IPM-style, per-lane proxy): -z * upload
        # reverses the aggregate's direction with z-fold weight
        return jax.tree.map(
            lambda t: jnp.where(
                corrupted,
                (-cfg.attack_z * t.astype(jnp.float32)).astype(t.dtype),
                t), upload)
    if mode in ("nan", "inf"):
        v = float("nan") if mode == "nan" else float("inf")
        return jax.tree.map(
            lambda t: jnp.where(corrupted, jnp.full_like(t, v), t), upload)
    if mode == "signflip":
        return jax.tree.map(
            lambda t: jnp.where(corrupted, -t, t), upload)
    if mode == "scale":
        return jax.tree.map(
            lambda t: jnp.where(
                corrupted,
                (cfg.corrupt_scale * t.astype(jnp.float32)).astype(t.dtype),
                t), upload)
    # bitflip (dense fallback): per-leaf keys so flips are independent
    leaves, treedef = jax.tree_util.tree_flatten(upload)
    out = [_bitflip_array(t, jax.random.fold_in(key, i), cfg.bitflip_frac,
                          corrupted)
           for i, t in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def wire_corruptor(cfg: FaultConfig, corrupted, key
                   ) -> Optional[Callable[[jax.Array], jax.Array]]:
    """Single-buffer corruption hook for ``Compressor.roundtrip``: only
    'bitflip' targets the wire representation (the compressed codes);
    the Byzantine/non-finite modes damage the decoded payload instead
    (``corrupt_payload``)."""
    if cfg.corrupt_mode != "bitflip":
        return None

    def flip(buf: jax.Array) -> jax.Array:
        return _bitflip_array(buf, key, cfg.bitflip_frac, corrupted)

    return flip


def screen_upload(cfg: FaultConfig, upload: Pytree, dropped
                  ) -> Tuple[Pytree, jax.Array, Dict[str, jax.Array]]:
    """Server-side screening of one lane: ``(clean_upload, weight,
    fault_metrics)``.

    * non-finite detection: any NaN/Inf leaf -> weight 0;
    * dropped lanes -> weight 0 (no upload exists);
    * ``clip_norm`` > 0: over-norm uploads are SCALED down to the clip
      (weight in (0, 1]), standard norm clipping against Byzantine
      magnitude attacks;
    * every zero-weight lane's VALUES are zeroed too -- the weighted mean
      multiplies by the weight, and 0 * NaN would still be NaN inside the
      psum.

    Shard-local by construction (per-lane math only): the engine lowers
    the resulting (m,) weight vector into the round's single psum."""
    leaves = jax.tree.leaves(upload)
    finite = jnp.asarray(True)
    for t in leaves:
        finite = jnp.logical_and(
            finite, jnp.all(jnp.isfinite(t.astype(jnp.float32))))
    ok = jnp.logical_and(finite, jnp.logical_not(dropped))
    if cfg.clip_norm > 0:
        sq = sum(jnp.sum(jnp.square(t.astype(jnp.float32)))
                 for t in leaves)
        # NaN norms are gated by ok=False below.  Zero-norm edge: an
        # exactly-zero upload hits sq=0, the max floors it at 1e-30, and
        # rsqrt(1e-30) ~ 3.2e13 * clip_norm blows past 1 -- the OUTER
        # min is what pins its scale to exactly 1.0 (full weight, values
        # untouched).  Both clauses are load-bearing; dropping either
        # turns a zero upload into inf*0 inside the psum.  Pinned by
        # test_screen_upload_zero_norm_scale_is_one.
        scale = jnp.minimum(
            1.0, cfg.clip_norm * jax.lax.rsqrt(jnp.maximum(sq, 1e-30)))
    else:
        scale = jnp.asarray(1.0, jnp.float32)
    w = jnp.where(ok, scale, 0.0).astype(jnp.float32)
    zero_gate = jnp.logical_not(ok)
    clean = jax.tree.map(
        lambda t: jnp.where(zero_gate, jnp.zeros_like(t), t), upload)
    fm = {
        "screened": 1.0 - ok.astype(jnp.float32),  # lanes w/ zero weight
        "dropped": dropped.astype(jnp.float32),
    }
    return clean, w, fm
