"""Client fault injection, upload screening, and rng-salted schedules."""
from repro.faults.inject import (CORRUPT_MODES, FaultConfig, corrupt_payload,
                                 fault_draws, fault_round_keys, make_faults,
                                 screen_upload, wire_corruptor)

__all__ = [
    "CORRUPT_MODES", "FaultConfig", "corrupt_payload", "fault_draws",
    "fault_round_keys", "make_faults", "screen_upload", "wire_corruptor",
]
