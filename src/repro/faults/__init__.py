"""Client fault injection, upload screening, and rng-salted schedules."""
from repro.faults.inject import (CORRUPT_MODES, STEALTH_MODES, FaultConfig,
                                 attack_round_key, corrupt_payload,
                                 fault_draws, fault_round_keys, make_faults,
                                 needs_attack_key, screen_upload,
                                 wire_corruptor)

__all__ = [
    "CORRUPT_MODES", "STEALTH_MODES", "FaultConfig", "attack_round_key",
    "corrupt_payload", "fault_draws", "fault_round_keys", "make_faults",
    "needs_attack_key", "screen_upload", "wire_corruptor",
]
