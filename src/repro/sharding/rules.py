"""PartitionSpec rules: parameter, client-state, batch and cache shardings.

Key-name driven: the last dict key on a leaf's path determines the
*logical* template for its trailing dims ('O' = output-feature dim ->
tensor-parallel over the model axis, 'I' = input-feature dim -> FSDP axis,
'E' = expert dim -> expert-parallel over the model axis, ...).  Extra
leading dims (lax.scan layer stacking, client axes) are unsharded / client
sharded.  Every axis assignment is divisibility-checked against the mesh
and dropped (replicated) when it does not divide -- so one rule set serves
all 10 architectures on any mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# state-dict keys holding per-client stores (leading n_clients dim):
# client state, personal models, and the compressor's error-feedback
# residuals.  One constant so the layout contract (`sim_state_specs`),
# the checkpoint tree, and the fault/rollback machinery agree on which
# entries are client-row-indexed.
CLIENT_STORE_KEYS: Tuple[str, ...] = ("clients", "pms", "ef")

# logical template per trailing-dims, keyed by the leaf's last path key.
#   O: out-feature  -> model axis (tensor parallel)
#   I: in-feature   -> fsdp axis (multi-pod ZeRO-style)
#   E: expert       -> model axis (expert parallel)
#   V: vocab        -> model axis
#   .: never sharded
_KEY_RULES: Dict[str, Tuple[str, ...]] = {
    # embeddings / heads
    "embed": ("V", "I"),
    "lm_head": ("I", "V"),
    # attention / generic projections (in, out)
    "wq": ("I", "O"), "wk": ("I", "O"), "wv": ("I", "O"),
    "wo": ("O", "I"),
    "bq": ("O",), "bk": ("O",), "bv": ("O",),
    # MLA
    "wdq": ("I", "O"), "wuq": ("I", "O"), "wdkv": ("I", "O"),
    "wuk": ("I", "O"), "wuv": ("I", "O"),
    # dense ffn
    "w_up": ("I", "O"), "w_gate": ("I", "O"), "w_down": ("O", "I"),
    "ff_gate": ("I", "O"), "ff_down": ("O", "I"),
    # moe expert weights (E, d, f): expert-parallel over the model axis
    "we_gate": ("E", "I", "."), "we_up": ("E", "I", "."),
    "we_down": ("E", ".", "I"),
    "router": ("I", "."),
    # ssm / xlstm
    "in_proj": ("I", "O"), "out_proj": ("O", "I"),
    "x_proj": ("O", "."), "dt_proj": (".", "O"),
    "conv_w": (".", "O"), "conv_b": ("O",),
    "dt_bias": ("O",), "A_log": ("O", "."), "D": ("O",),
    "up": ("I", "O"), "down": ("O", "I"),
    "w_if": ("O", "."), "b_if": (".",),
    "w_in": ("I", "O"), "r": (".", ".", "."), "b": (".",),
    # misc
    "proj": ("I", "O"),  # mtp combiner
    "scale": (".",),
}

def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _axis_ok(mesh_sizes, axis: Optional[str], dim: int) -> bool:
    return axis is not None and axis in mesh_sizes and \
        dim % mesh_sizes[axis] == 0


def logical_template(path, ndim: int) -> Tuple[str, ...]:
    key = _path_keys(path)[-1]
    base = _KEY_RULES.get(key, (".",) * ndim)
    # pad leading stacked dims (lax.scan layer stacking) with '.'
    if ndim > len(base):
        base = (".",) * (ndim - len(base)) + tuple(base)
    elif ndim < len(base):
        base = tuple(base[-ndim:])
    return tuple(base)


def param_pspec(path, shape, *, model: str = "model",
                fsdp: Optional[str] = None, mesh_sizes=None) -> P:
    tmpl = logical_template(path, len(shape))
    out = []
    expert_failed = False
    for sym, dim in zip(tmpl, shape):
        axis = None
        if sym in ("O", "E", "V"):
            axis = model
        elif sym == "I":
            axis = fsdp
        if not _axis_ok(mesh_sizes, axis, dim):
            if sym == "E":
                expert_failed = True
            axis = None
        out.append(axis)
    if expert_failed:
        # expert count doesn't divide the model axis (e.g. granite's 40
        # experts on 16 chips): fall back to tensor parallelism *within*
        # each expert, megatron-style -- shard the per-expert hidden dim
        # ('.' in the template: f for w_gate/w_up/w_down) so gate/up are
        # column-parallel and down is row-parallel (one all-reduce).
        for prefer_dot in (True, False):
            done = False
            for i, (sym, dim) in enumerate(zip(tmpl, shape)):
                if sym == "E" or out[i] is not None:
                    continue
                if prefer_dot and sym != ".":
                    continue
                if _axis_ok(mesh_sizes, model, dim):
                    out[i] = model
                    done = True
                    break
            if done:
                break
    return P(*out)


def client_store_pspec(path, shape, *, client: str, mesh_sizes,
                       model: str = "model",
                       fsdp: Optional[str] = None) -> P:
    """Spec for one leaf of a per-client store (leading n_clients dim,
    trailing params dims).  The client dim takes the client axis when
    ``n_clients`` divides it and falls back to REPLICATED otherwise --
    never an error -- so the cohort engine's mesh placement runs with any
    n; the trailing dims follow the parameter rules."""
    spec = param_pspec(path, shape[1:], model=model, fsdp=fsdp,
                       mesh_sizes=mesh_sizes)
    cax = client if _axis_ok(mesh_sizes, client, shape[0]) else None
    return P(cax, *spec)


def param_specs(shapes: Pytree, mesh: Mesh, *, model: str = "model",
                fsdp: Optional[str] = None,
                client: Optional[str] = None) -> Pytree:
    """NamedSharding pytree for a params(-shaped) pytree.  ``client``
    prepends a client axis for per-client state (leading C dim)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    out = []
    for path, leaf in flat:
        shape = leaf.shape
        if client is not None:
            spec = client_store_pspec(path, shape, client=client,
                                      model=model, fsdp=fsdp,
                                      mesh_sizes=sizes)
        else:
            spec = param_pspec(path, shape, model=model, fsdp=fsdp,
                               mesh_sizes=sizes)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, [s for s in out])


def upload_stack_specs(uploads: Pytree, mesh: Mesh, *, client: str,
                       model: str = "model",
                       fsdp: Optional[str] = None) -> Pytree:
    """NamedSharding pytree for a stacked upload buffer (leading m dim):
    the async-on-mesh aggregation operand layout.  The buffer axis takes
    the client axis when m divides it -- on the mesh async path callers
    pad the buffer to a multiple of the axis (``engine.pad_cohort``), so
    it always does there -- with the same replicated fallback and
    trailing-dim rules as the client/pms stores (``client_store_pspec``):
    one rule set, three consumers."""
    return param_specs(uploads, mesh, model=model, fsdp=fsdp,
                       client=client)


def sim_state_specs(state: Pytree, mesh: Mesh, *, client: str,
                    model: str = "model",
                    fsdp: Optional[str] = None) -> Pytree:
    """NamedSharding pytree for a whole simulation-state dict (the cohort
    engine's ``{x, clients, pms, server, rng, round}`` plus, under a
    stateful uplink compressor, the error-feedback store ``ef``): the
    per-client stores (``clients``/``pms``/``ef``, leading n_clients dim)
    follow ``client_store_pspec`` -- client axis on dim 0 when n_clients
    divides it, replicated fallback otherwise -- and every other entry is
    replicated.

    One function owns this layout because two consumers must agree on it:
    ``MeshPlacement.place_state`` materializes it with ``device_put``, and
    the scan-compiled block driver carries the state through ``lax.scan``
    expecting the round body to re-pin its outputs to the same specs (so
    the carry never reshards between scanned rounds)."""
    rep = NamedSharding(mesh, P())
    out = {}
    for key, sub in state.items():
        if key in CLIENT_STORE_KEYS and jax.tree.leaves(sub):
            out[key] = param_specs(sub, mesh, model=model, fsdp=fsdp,
                                   client=client)
        else:
            out[key] = jax.tree.map(lambda t: rep, sub)
    return out


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------

def train_batch_spec(mesh: Mesh, *, client: str, fsdp: Optional[str] = None,
                     batch_dims: int = 2):
    """Round batch (C, tau, b, S[, ...]): C over the client axis, b over the
    fsdp axis (multi-pod)."""
    def f(leaf_ndim: int) -> P:
        spec = [client, None, fsdp]
        spec += [None] * (leaf_ndim - 3)
        return P(*spec)

    return f


def data_parallel_spec(mesh: Mesh, axes) -> P:
    """Batch (B, ...) sharded over the given axes tuple on dim 0."""
    return P(axes)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_specs(cache_shapes: Pytree, mesh: Mesh, *, model: str = "model",
                dp: Any = None, prefer_seq: bool = False) -> Pytree:
    """KV/state cache shardings for serving.

    Per leaf (B, L, ...trailing): B over the data-parallel axes when
    divisible; then the *largest* trailing dim over the model axis when
    divisible (kv heads for K%16==0, latent r for MLA, d_inner for SSM
    states); when heads don't divide (kv=8 archs) the sequence dim L takes
    the model axis instead -- sequence-parallel decode attention, which
    GSPMD lowers with a cross-shard softmax reduction."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes[model]

    def one(leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        dp_axes = tuple(a for a in (dp if isinstance(dp, tuple) else (dp,))
                        if a)
        if dp_axes:
            n = int(np.prod([sizes[a] for a in dp_axes]))
            if shape[0] % n == 0:
                spec[0] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        # trailing dims: optionally force the sequence dim (dim 1, for the
        # shard_map flash-decode path), else largest-first for model axis
        rest = list(range(1, len(shape)))
        if not prefer_seq:
            rest.sort(key=lambda i: -shape[i])
        for i in rest:
            if shape[i] % msize == 0 and shape[i] >= msize:
                spec[i] = model
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_shapes)
