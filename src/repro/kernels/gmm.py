"""Grouped matmul (MoE expert compute) as a Pallas TPU kernel.

``(E, T, d) x (E, d, f) -> (E, T, f)`` -- one matmul per expert over its
capacity buffer.  This is MegaBlocks' grouped GEMM rethought for the MXU:
grid (E, T/bt, f/bf, d/bd) with a float32 VMEM accumulator carried across
the contraction dimension (sequential innermost grid axis), 128-aligned
blocks feeding the 128x128 systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import pick_block


def _kernel(nd, x_ref, w_ref, o_ref, acc_ref):
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


_pick = pick_block  # shared tiling util (kept under the historical name)


def gmm_pallas(x, w, *, block_t: int = 256, block_f: int = 256,
               block_d: int = 512, interpret: bool = False):
    """x: (E, T, d), w: (E, d, f) -> (E, T, f)."""
    E, T, d = x.shape
    _, _, f = w.shape
    bt, bf, bd = _pick(T, block_t), _pick(f, block_f), _pick(d, block_d)
    grid = (E, T // bt, f // bf, d // bd)
    kernel = functools.partial(_kernel, d // bd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bt, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, T, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
