"""Blocked online-softmax (flash) attention as a Pallas TPU kernel.

Grid (B*H, num_q_blocks, num_kv_blocks) iterated sequentially on TPU;
running max / sum / accumulator live in VMEM scratch across the kv
dimension (the "revisiting" pattern).  GQA is handled in the index maps:
query head h reads kv head h // G -- no materialized broadcast of K/V.
Causal + sliding-window masking is positional; fully-masked blocks are
skipped with ``pl.when`` (halves the FLOPs of causal attention).

MXU alignment: q/k/v blocks are (block_q|block_kv, head_dim) with
head_dim padded to a multiple of 128 by the wrapper in ops.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(scale, causal, window, cap, block_q, block_kv, nk,
            q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_kv

    def compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bkv, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new) * mask  # zero fully-masked rows exactly
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)

    if causal or window is not None:
        # skip blocks that are fully masked
        live = jnp.asarray(True)
        if causal:
            live &= k_start <= q_start + block_q - 1
        if window is not None:
            live &= (q_start - (k_start + block_kv - 1)) < window
        pl.when(live)(compute)
    else:
        compute()

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# flash decode: one-token queries against a KV cache with per-row live lens
# ---------------------------------------------------------------------------

def _decode_kernel(scale, cap, block_kv, nk,
                   q_ref, k_ref, v_ref, len_ref, o_ref,
                   m_scr, l_scr, acc_scr):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = len_ref[0, 0]
    k_start = j * block_kv

    def compute():
        q = q_ref[0].astype(jnp.float32)  # (G, Dq)
        k = k_ref[0].astype(jnp.float32)  # (bkv, Dq)
        v = v_ref[0].astype(jnp.float32)  # (bkv, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], block_kv), 1)
        mask = kpos < valid
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)

    # a block entirely past the row's live length is a bitwise no-op
    # (mask zeroes p exactly; corr == exp(0) == 1), so skipping it only
    # saves FLOPs -- short rows in a mixed batch pay for their own length
    pl.when(k_start < valid)(compute)

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode_bhsd(q, k, v, lens, *, cap: Optional[float] = None,
                      block_kv: int = 128, interpret: bool = False):
    """q: (B*K, G, Dq) one-token queries, k: (B*K, L, Dq),
    v: (B*K, L, Dv), lens: (B*K,) int32 live lengths -- head-major.

    The wrapper in ops.py handles (B,1,H,D) <-> head-major reshapes,
    head-dim / group / length padding, and the off-TPU oracle bypass."""
    BK, G, Dq = q.shape
    _, L, Dv = v.shape
    assert L % block_kv == 0
    nk = L // block_kv
    scale = Dq ** -0.5
    lens2 = lens.astype(jnp.int32).reshape(BK, 1)

    kernel = functools.partial(_decode_kernel, scale, cap, block_kv, nk)
    return pl.pallas_call(
        kernel,
        grid=(BK, nk),
        in_specs=[
            pl.BlockSpec((1, G, Dq), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, Dq), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, Dv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, G, Dv), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BK, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max
            pltpu.VMEM((G, 1), jnp.float32),   # running sum
            pltpu.VMEM((G, Dv), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v, lens2)


def flash_decode_ref(q, k, v, lens, *, cap: Optional[float] = None,
                     block_kv: int = 128):
    """Pure-jnp oracle running the SAME blocked online-softmax math as
    ``_decode_kernel`` on the same head-major operands (scan over KV
    blocks, vmapped over rows).  Bitwise-identical to the interpret-mode
    kernel, which makes it both the correctness pin and the off-TPU fast
    path in ops.flash_decode (interpret-mode grid emulation copies full
    buffers per grid step)."""
    BK, G, Dq = q.shape
    _, L, Dv = v.shape
    assert L % block_kv == 0
    nk = L // block_kv
    scale = Dq ** -0.5

    def one_row(qr, kr, vr, valid):
        kb = kr.reshape(nk, block_kv, Dq)
        vb = vr.reshape(nk, block_kv, Dv)
        starts = jnp.arange(nk, dtype=jnp.int32) * block_kv

        def step(carry, blk):
            m_prev, l_prev, acc = carry
            kj, vj, k_start = blk
            s = jax.lax.dot_general(qr, kj, (((1,), (1,)), ((), ()))) * scale
            if cap is not None:
                s = cap * jnp.tanh(s / cap)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (G, block_kv), 1)
            mask = kpos < valid
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
            p = jnp.exp(s - m_new) * mask
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
            acc = acc * corr + jax.lax.dot(p, vj)
            return (m_new, l_new, acc), None

        init = (jnp.full((G, 1), NEG_INF, jnp.float32),
                jnp.zeros((G, 1), jnp.float32),
                jnp.zeros((G, Dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, starts))
        return acc / jnp.maximum(l, 1e-30)

    out = jax.vmap(one_row)(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), lens.astype(jnp.int32))
    return out.astype(q.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True,
                         window: Optional[int] = None,
                         cap: Optional[float] = None,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: bool = False):
    """q: (B*H, S, D), k/v: (B*K, S, D) -- head-major layout.

    The wrapper in ops.py handles (B,S,H,D) <-> head-major reshapes and
    head-dim padding."""
    BH, Sq, D = q.shape
    BK, Sk, _ = k.shape
    G = BH // BK  # query heads per kv head (within a batch row group)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    assert Sq % block_q == 0 and Sk % block_kv == 0
    nq, nk = Sq // block_q, Sk // block_kv
    scale = D ** -0.5

    kernel = functools.partial(_kernel, scale, causal, window, cap,
                               block_q, block_kv, nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda h, i, j: (h // G, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda h, i, j: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
