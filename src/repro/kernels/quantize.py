"""Stochastic int8 pack / unpack of the TreeFlattener-packed buffer.

The comm layer's q8 compressor normalizes every upload leaf by its own
scale (per-leaf ``amax / 127``), packs the whole tree into ONE padded
``(rows, LANES)`` float32 buffer (``kernels.tiling.TreeFlattener``), and
quantizes it with a single Pallas launch -- the same launch-count
argument as the fused ``deper_update``: at 8 leaves per MLP a per-leaf
quantizer would cost 8 launches per upload, and launch overhead, not
bandwidth, dominates elementwise passes.

Pack (stochastic rounding, unbiased: E[q] = v for v pre-scaled into
[-127, 127]):

    q = clip(floor(v + u), -127, 127).astype(int8),   u ~ U[0, 1)

The uniform draws arrive as a kernel *operand* (generated with
``jax.random`` outside) instead of ``pltpu.prng_*`` so the identical
kernel body runs under ``interpret=True`` off-TPU and stays bitwise
against the jnp oracle the tests pin.

Unpack is the exact inverse modulo rounding: ``q.astype(f32)`` (the
caller multiplies the per-leaf scales back after unflattening).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import LANES  # noqa: F401  (re-exported)

DEFAULT_BLOCK_ROWS = 256

QMAX = 127.0  # symmetric int8 range; -128 is never emitted


def _kernel_pack(v_ref, r_ref, o_ref):
    v = v_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.clip(jnp.floor(v + r), -QMAX, QMAX).astype(jnp.int8)


def _kernel_unpack(q_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32)


def quantize_stochastic_2d(v, rand, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                           interpret: bool = False):
    """(R, LANES) f32 pre-scaled into [-QMAX, QMAX] + U[0,1) draws of the
    same shape -> int8 (R, LANES), one launch."""
    R, L = v.shape
    assert L == LANES and R % block_rows == 0, (v.shape, block_rows)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel_pack,
        grid=(R // block_rows,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(v.shape, jnp.int8),
        interpret=interpret,
    )(v, rand)


def dequantize_2d(q, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False):
    """int8 (R, LANES) -> f32 (R, LANES), one launch."""
    R, L = q.shape
    assert L == LANES and R % block_rows == 0, (q.shape, block_rows)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel_unpack,
        grid=(R // block_rows,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=interpret,
    )(q)
