"""Shared tiling utilities for the Pallas kernels.

Two things live here because more than one kernel needs them:

* ``pick_block`` -- the largest block <= target that divides n (lifted out
  of the grouped-matmul kernel, where it was private);
* ``TreeFlattener`` -- packs a whole parameter pytree into ONE padded
  ``(rows, LANES)`` float32 buffer so elementwise kernels launch once per
  *pytree* instead of once per *leaf*.  The FedDeper update touches every
  parameter every local step; at 8 leaves per MLP that was 8 kernel
  launches per step, and launch overhead -- not bandwidth -- dominated.

The flattener is built at trace time from the tree's (static) shapes, so
it composes with ``jax.jit``/``vmap``: ``flatten`` is a single concatenate
(zero tail included, one copy) and ``unflatten`` is static slices.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

LANES = 1024  # 8 sublanes x 128 lanes (f32 VPU tile, see pallas guide)


def pick_block(n: int, target: int) -> int:
    """Largest block size <= target that evenly divides n."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


class TreeFlattener:
    """Pack a pytree of arrays into one padded ``(rows, LANES)`` buffer.

    ``block_rows=None`` keeps the whole buffer as a single block (one grid
    step -- right for CPU/interpret and for trees that fit VMEM); a TPU
    caller passes a row-block target and the padded row count is rounded
    UP to a multiple of it, so the grid never degenerates to block=1 on
    awkward (e.g. prime) row counts.
    """

    def __init__(self, tree: Pytree, block_rows: int | None = None,
                 lanes: int = LANES):
        leaves = jax.tree.leaves(tree)
        self.treedef = jax.tree.structure(tree)
        self.shapes: List[Tuple[int, ...]] = [l.shape for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.sizes = [int(np.prod(s, dtype=np.int64)) for s in self.shapes]
        self.offsets = np.cumsum([0] + self.sizes).tolist()
        self.size = self.offsets[-1]
        self.lanes = lanes
        rows = max(1, -(-self.size // lanes))
        self.block_rows = rows if block_rows is None else min(block_rows,
                                                              rows)
        self.rows = -(-rows // self.block_rows) * self.block_rows
        self.padded = self.rows * lanes

    @property
    def grid(self) -> Tuple[int, ...]:
        return (self.rows // self.block_rows,)

    def flatten(self, tree: Pytree) -> jax.Array:
        """Tree (matching this flattener's structure) -> (rows, LANES)
        float32 buffer.  One concatenate, zero tail included."""
        parts = [l.reshape(-1).astype(jnp.float32)
                 for l in jax.tree.leaves(tree)]
        if self.padded > self.size:
            parts.append(jnp.zeros((self.padded - self.size,), jnp.float32))
        return jnp.concatenate(parts).reshape(self.rows, self.lanes)

    def unflatten(self, buf: jax.Array) -> Pytree:
        """(rows, LANES) buffer -> tree with the original shapes/dtypes."""
        flat = buf.reshape(-1)
        leaves = [
            jax.lax.slice(flat, (o,), (o + s,)).reshape(sh).astype(dt)
            for o, s, sh, dt in zip(self.offsets, self.sizes, self.shapes,
                                    self.dtypes)
        ]
        return jax.tree.unflatten(self.treedef, leaves)
