"""Fused FedDeper alternating update as a Pallas TPU kernel.

The paper's local step (Alg. 1 lines 7-8) touches five same-shaped arrays
(y, v, x, gy, gv) and writes two.  Executed as separate XLA ops the update
phase costs ~10 HBM array passes; fused it is exactly 5 reads + 2 writes.
For the datacenter regime (72B-scale client models) the update phase is
purely memory-bound, so pass count == wall time.

With ``lam`` given, the same launch additionally emits the round tail --
the mixing step v+ = (1-lam) v' + lam y' (Alg. 1 line 10) and the upload
y' - x (line 11) -- while the operands are already in VMEM: 5 reads + 4
writes, versus 5r+2w followed by a separate 3r+2w pass.

Tiling: inputs are flattened and padded to (rows, 1024) -- 8x128 VPU lanes
-- and blocked over rows; all five operands stream through VMEM.  The
whole-pytree packing (one launch per *step*, not per *leaf*) lives in
``kernels.tiling.TreeFlattener``; this module only sees 2-D buffers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import LANES  # noqa: F401  (re-exported)

DEFAULT_BLOCK_ROWS = 256


def _kernel(eta, rho, y_ref, v_ref, x_ref, gy_ref, gv_ref, yo_ref, vo_ref):
    y = y_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    gy = gy_ref[...].astype(jnp.float32)
    gv = gv_ref[...].astype(jnp.float32)
    yo_ref[...] = (y - eta * gy - rho * (v + y - 2.0 * x)).astype(
        yo_ref.dtype)
    vo_ref[...] = (v - eta * gv).astype(vo_ref.dtype)


def _kernel_mix(eta, rho, lam, y_ref, v_ref, x_ref, gy_ref, gv_ref,
                yo_ref, vo_ref, mo_ref, uo_ref):
    y = y_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    gy = gy_ref[...].astype(jnp.float32)
    gv = gv_ref[...].astype(jnp.float32)
    y_new = y - eta * gy - rho * (v + y - 2.0 * x)
    v_new = v - eta * gv
    yo_ref[...] = y_new.astype(yo_ref.dtype)
    vo_ref[...] = v_new.astype(vo_ref.dtype)
    mo_ref[...] = ((1.0 - lam) * v_new + lam * y_new).astype(mo_ref.dtype)
    uo_ref[...] = (y_new - x).astype(uo_ref.dtype)


def deper_update_2d(y, v, x, gy, gv, *, eta: float, rho: float,
                    lam: Optional[float] = None,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = False):
    """All operands (R, LANES).  Returns (y', v'), or with ``lam`` the
    4-tuple (y', v', (1-lam) v' + lam y', y' - x) from one launch."""
    R, L = y.shape
    assert L == LANES and R % block_rows == 0, (y.shape, block_rows)
    grid = (R // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    # y'/upload keep y's dtype, v'/mix keep v's (mix replaces v)
    out_shape = [jax.ShapeDtypeStruct(y.shape, y.dtype),
                 jax.ShapeDtypeStruct(v.shape, v.dtype)]
    if lam is not None:
        out_shape += [jax.ShapeDtypeStruct(v.shape, v.dtype),
                      jax.ShapeDtypeStruct(y.shape, y.dtype)]
    kernel = (functools.partial(_kernel, eta, rho) if lam is None
              else functools.partial(_kernel_mix, eta, rho, lam))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * len(out_shape),
        out_shape=out_shape,
        interpret=interpret,
    )(y, v, x, gy, gv)
