"""Fused FedDeper alternating update as a Pallas TPU kernel.

The paper's local step (Alg. 1 lines 7-8) touches five same-shaped arrays
(y, v, x, gy, gv) and writes two.  Executed as separate XLA ops the update
phase costs ~10 HBM array passes; fused it is exactly 5 reads + 2 writes.
For the datacenter regime (72B-scale client models) the update phase is
purely memory-bound, so pass count == wall time.

Tiling: inputs are flattened and padded to (rows, 1024) -- 8x128 VPU lanes
-- and blocked over rows; all five operands stream through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024  # 8 sublanes x 128 lanes
DEFAULT_BLOCK_ROWS = 256


def _kernel(eta, rho, y_ref, v_ref, x_ref, gy_ref, gv_ref, yo_ref, vo_ref):
    y = y_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    gy = gy_ref[...].astype(jnp.float32)
    gv = gv_ref[...].astype(jnp.float32)
    yo_ref[...] = (y - eta * gy - rho * (v + y - 2.0 * x)).astype(
        yo_ref.dtype)
    vo_ref[...] = (v - eta * gv).astype(vo_ref.dtype)


def deper_update_2d(y, v, x, gy, gv, *, eta: float, rho: float,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = False):
    """All operands (R, LANES); returns (y', v')."""
    R, L = y.shape
    assert L == LANES and R % block_rows == 0, (y.shape, block_rows)
    grid = (R // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, eta, rho),
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(y.shape, y.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        interpret=interpret,
    )(y, v, x, gy, gv)
