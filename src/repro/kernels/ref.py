"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def deper_update_ref(y, v, x, gy, gv, *, eta: float, rho: float):
    """FedDeper alternating update (Alg. 1 lines 7-8), one array:

        y' = y - eta*gy - rho*(v + y - 2x)
        v' = v - eta*gv
    """
    y_new = y - eta * gy - rho * (v + y - 2.0 * x)
    v_new = v - eta * gv
    return y_new.astype(y.dtype), v_new.astype(v.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        cap: Optional[float] = None):
    """q: (B,S,H,D), k/v: (B,S,K,D), H = K*G.  Materializing oracle."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, G, D)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qf, k.astype(jnp.float32))
    s = s * (D ** -0.5)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[:, None] >= idx[None, :]
    if window is not None:
        mask &= (idx[:, None] - idx[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqj,bjkd->bkgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D).astype(q.dtype)


def gmm_ref(x, w):
    """Grouped matmul: (E, T, d) x (E, d, f) -> (E, T, f)."""
    return jnp.einsum("etd,edf->etf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
