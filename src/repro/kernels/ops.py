"""Jit'd public wrappers around the Pallas kernels.

On non-TPU backends (this CPU container) the kernels execute in
``interpret=True`` mode -- the kernel body runs step-by-step in Python/XLA
for correctness validation; on TPU they compile natively.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import deper_update as _deper
from repro.kernels import flash_attention as _flash
from repro.kernels import gmm as _gmm
from repro.kernels.tiling import TreeFlattener, pick_block


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# deper_update over pytrees
# ---------------------------------------------------------------------------

def _leaf_update(y, v, x, gy, gv, *, eta, rho):
    shape, dtype = y.shape, y.dtype
    n = y.size
    L = _deper.LANES
    rows = max(1, -(-n // L))
    # pick a row block that divides the padded row count
    block = pick_block(rows, _deper.DEFAULT_BLOCK_ROWS)

    def prep(t):
        t = t.reshape(-1).astype(jnp.float32)
        return jnp.pad(t, (0, rows * L - n)).reshape(rows, L)

    y2, v2 = _deper.deper_update_2d(
        prep(y), prep(v), prep(x), prep(gy), prep(gv), eta=eta, rho=rho,
        block_rows=block, interpret=_interpret())
    return (y2.reshape(-1)[:n].reshape(shape).astype(dtype),
            v2.reshape(-1)[:n].reshape(shape).astype(dtype))


@functools.partial(jax.jit, static_argnames=("eta", "rho"))
def deper_update_per_leaf(y, v, x, gy, gv, *, eta: float, rho: float):
    """Unfused reference: one kernel launch PER PYTREE LEAF (the pre-
    round-engine hot path).  Kept as the equivalence baseline and the
    ``fuse_grads=False`` escape hatch; new code wants ``deper_update``,
    which launches once per step."""
    flat_y, treedef = jax.tree.flatten(y)
    flat = [
        _leaf_update(yl, vl, xl, gyl, gvl, eta=eta, rho=rho)
        for yl, vl, xl, gyl, gvl in zip(
            flat_y, jax.tree.leaves(v), jax.tree.leaves(x),
            jax.tree.leaves(gy), jax.tree.leaves(gv))
    ]
    y_new = jax.tree.unflatten(treedef, [f[0] for f in flat])
    v_new = jax.tree.unflatten(treedef, [f[1] for f in flat])
    return y_new, v_new


def _flat_update(yf, vf, xf, gyf, gvf, *, eta, rho, lam, block):
    """Single-launch fused update on (rows, LANES) buffers.  On TPU this
    is one ``pallas_call``; elsewhere the identical kernel math runs as
    one fused XLA elementwise op (interpret-mode grid emulation costs a
    full-buffer copy per operand per grid step, which would defeat the
    launch fusion this path exists for).  Both are the same f32
    elementwise expression, so results are bitwise equal."""
    if not _interpret():
        return _deper.deper_update_2d(yf, vf, xf, gyf, gvf, eta=eta,
                                      rho=rho, lam=lam, block_rows=block)
    y_new = yf - eta * gyf - rho * (vf + yf - 2.0 * xf)
    v_new = vf - eta * gvf
    if lam is None:
        return y_new, v_new
    return (y_new, v_new, (1.0 - lam) * v_new + lam * y_new, y_new - xf)


@functools.partial(jax.jit, static_argnames=("eta", "rho", "lam"))
def deper_update(y, v, x, gy, gv, *, eta: float, rho: float,
                 lam: Optional[float] = None):
    """Fused FedDeper update over parameter pytrees, ONE launch per step:
    the whole tree is packed into a single padded (rows, LANES) buffer
    (``TreeFlattener``), so launch count is independent of leaf count.

    Returns (y', v'); with ``lam`` the same launch also emits the round
    tail, returning (y', v', v_mixed, upload) where
    ``v_mixed = (1-lam) v' + lam y'`` and ``upload = y' - x``.

    Dtypes follow the 2-D kernel contract: y'/upload keep y's leaf
    dtypes, v'/v_mixed keep v's (they replace v).
    """
    block = None if _interpret() else _deper.DEFAULT_BLOCK_ROWS
    fl_y = TreeFlattener(y, block_rows=block)
    fl_v = TreeFlattener(v, block_rows=block)  # same shapes, v's dtypes
    out = _flat_update(fl_y.flatten(y), fl_v.flatten(v), fl_y.flatten(x),
                       fl_y.flatten(gy), fl_v.flatten(gv), eta=eta,
                       rho=rho, lam=lam, block=fl_y.block_rows)
    unflatteners = (fl_y, fl_v, fl_v, fl_y)
    return tuple(f.unflatten(o) for f, o in zip(unflatteners, out))


# ---------------------------------------------------------------------------
# stochastic int8 pack / unpack (comm layer's q8 compressor)
# ---------------------------------------------------------------------------

def quantize_stochastic(buf, rand):
    """Stochastically round a TreeFlattener-packed (rows, LANES) f32
    buffer (pre-scaled into [-127, 127]) to int8: ONE ``pallas_call`` on
    TPU; elsewhere the identical kernel expression runs as one fused XLA
    elementwise op (interpret-mode grid emulation copies full buffers per
    grid step -- same rationale as ``_flat_update``).  Bitwise equal on
    both paths."""
    from repro.kernels import quantize as _q
    if not _interpret():
        block = pick_block(buf.shape[0], _q.DEFAULT_BLOCK_ROWS)
        return _q.quantize_stochastic_2d(buf, rand, block_rows=block)
    return jnp.clip(jnp.floor(buf + rand), -_q.QMAX, _q.QMAX).astype(
        jnp.int8)


def dequantize(q):
    """int8 packed buffer -> f32 (the caller re-applies per-leaf scales
    after unflattening)."""
    from repro.kernels import quantize as _q
    if not _interpret():
        block = pick_block(q.shape[0], _q.DEFAULT_BLOCK_ROWS)
        return _q.dequantize_2d(q, block_rows=block)
    return q.astype(jnp.float32)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "cap", "block_q",
                                    "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    cap: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128):
    """q: (B,S,H,D), k/v: (B,S,K,D) -> (B,S,H,D).  Pads D to 128."""
    B, S, H, D = q.shape
    K = k.shape[2]
    Dp = -(-D // 128) * 128
    pad = [(0, 0)] * 3 + [(0, Dp - D)]
    qp = jnp.pad(q, pad) if Dp != D else q
    kp = jnp.pad(k, pad) if Dp != D else k
    vp = jnp.pad(v, pad) if Dp != D else v
    # head-major: (B*H, S, D)
    qh = qp.transpose(0, 2, 1, 3).reshape(B * H, S, Dp)
    kh = kp.transpose(0, 2, 1, 3).reshape(B * K, S, Dp)
    vh = vp.transpose(0, 2, 1, 3).reshape(B * K, S, Dp)
    # scale uses the *unpadded* head dim
    scale_fix = (Dp / D) ** 0.5  # kernel scales by Dp^-0.5; correct to D^-0.5
    qh = qh * scale_fix
    out = _flash.flash_attention_bhsd(
        qh, kh, vh, causal=causal, window=window, cap=cap,
        block_q=block_q, block_kv=block_kv, interpret=_interpret())
    out = out.reshape(B, H, S, Dp).transpose(0, 2, 1, 3)
    return out[..., :D]


@functools.partial(jax.jit, static_argnames=("cap", "block_kv"))
def flash_decode(q, k_cache, v_cache, *, lens, cap: Optional[float] = None,
                 block_kv: int = 128):
    """Single-token GQA decode against a KV cache.

    q: (B,1,H,Dq), caches: (B,L,K,D*), ``lens``: scalar or (B,) live
    lengths per batch row -> (B,1,H,Dv), the drop-in flash counterpart
    of ``models.attention.decode_attention`` (global softmax there,
    blocked online softmax here; equal up to float reassociation).

    One Pallas launch on TPU; off-TPU the bitwise-identical blocked jnp
    oracle runs on the same padded head-major operands (interpret-mode
    grid emulation copies full buffers per grid step)."""
    B, _, H, Dq = q.shape
    _, L, K, Dv = v_cache.shape
    G = H // K
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (B,))

    # head-major rows: one grid row per (batch, kv-head) pair
    qh = q.reshape(B, K, G, Dq).reshape(B * K, G, Dq)
    kh = k_cache.transpose(0, 2, 1, 3).reshape(B * K, L, Dq)
    vh = v_cache.transpose(0, 2, 1, 3).reshape(B * K, L, Dv)
    lh = jnp.repeat(lens, K)

    # pad: head dims to the MXU lane width, query groups to a sublane
    # multiple, cache length to a whole number of KV blocks
    Dqp = -(-Dq // 128) * 128
    Dvp = -(-Dv // 128) * 128
    Gp = -(-G // 8) * 8
    bkv = min(block_kv, -(-L // 8) * 8)
    Lp = -(-L // bkv) * bkv
    qh = jnp.pad(qh, ((0, 0), (0, Gp - G), (0, Dqp - Dq)))
    kh = jnp.pad(kh, ((0, 0), (0, Lp - L), (0, Dqp - Dq)))
    vh = jnp.pad(vh, ((0, 0), (0, Lp - L), (0, Dvp - Dv)))
    # kernel scales by Dqp^-0.5; correct to Dq^-0.5 (padded tail is zero)
    qh = qh * (Dqp / Dq) ** 0.5

    if _interpret():
        out = _flash.flash_decode_ref(qh, kh, vh, lh, cap=cap,
                                      block_kv=bkv)
    else:
        out = _flash.flash_decode_bhsd(qh, kh, vh, lh, cap=cap,
                                       block_kv=bkv)
    out = out[:, :G, :Dv].reshape(B, 1, H, Dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@jax.jit
def gmm(x, w):
    """Grouped matmul (E,T,d)x(E,d,f)->(E,T,f) via the Pallas kernel."""
    return _gmm.gmm_pallas(x, w, interpret=_interpret())
