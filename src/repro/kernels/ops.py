"""Jit'd public wrappers around the Pallas kernels.

On non-TPU backends (this CPU container) the kernels execute in
``interpret=True`` mode -- the kernel body runs step-by-step in Python/XLA
for correctness validation; on TPU they compile natively.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import deper_update as _deper
from repro.kernels import flash_attention as _flash
from repro.kernels import gmm as _gmm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# deper_update over pytrees
# ---------------------------------------------------------------------------

def _leaf_update(y, v, x, gy, gv, *, eta, rho):
    shape, dtype = y.shape, y.dtype
    n = y.size
    L = _deper.LANES
    rows = max(1, -(-n // L))
    # pick a row block that divides the padded row count
    block = _gmm._pick(rows, _deper.DEFAULT_BLOCK_ROWS)

    def prep(t):
        t = t.reshape(-1).astype(jnp.float32)
        return jnp.pad(t, (0, rows * L - n)).reshape(rows, L)

    y2, v2 = _deper.deper_update_2d(
        prep(y), prep(v), prep(x), prep(gy), prep(gv), eta=eta, rho=rho,
        block_rows=block, interpret=_interpret())
    return (y2.reshape(-1)[:n].reshape(shape).astype(dtype),
            v2.reshape(-1)[:n].reshape(shape).astype(dtype))


@functools.partial(jax.jit, static_argnames=("eta", "rho"))
def deper_update(y, v, x, gy, gv, *, eta: float, rho: float):
    """Fused FedDeper update over parameter pytrees.  Returns (y', v')."""
    flat_y, treedef = jax.tree.flatten(y)
    flat = [
        _leaf_update(yl, vl, xl, gyl, gvl, eta=eta, rho=rho)
        for yl, vl, xl, gyl, gvl in zip(
            flat_y, jax.tree.leaves(v), jax.tree.leaves(x),
            jax.tree.leaves(gy), jax.tree.leaves(gv))
    ]
    y_new = jax.tree.unflatten(treedef, [f[0] for f in flat])
    v_new = jax.tree.unflatten(treedef, [f[1] for f in flat])
    return y_new, v_new


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "cap", "block_q",
                                    "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    cap: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128):
    """q: (B,S,H,D), k/v: (B,S,K,D) -> (B,S,H,D).  Pads D to 128."""
    B, S, H, D = q.shape
    K = k.shape[2]
    Dp = -(-D // 128) * 128
    pad = [(0, 0)] * 3 + [(0, Dp - D)]
    qp = jnp.pad(q, pad) if Dp != D else q
    kp = jnp.pad(k, pad) if Dp != D else k
    vp = jnp.pad(v, pad) if Dp != D else v
    # head-major: (B*H, S, D)
    qh = qp.transpose(0, 2, 1, 3).reshape(B * H, S, Dp)
    kh = kp.transpose(0, 2, 1, 3).reshape(B * K, S, Dp)
    vh = vp.transpose(0, 2, 1, 3).reshape(B * K, S, Dp)
    # scale uses the *unpadded* head dim
    scale_fix = (Dp / D) ** 0.5  # kernel scales by Dp^-0.5; correct to D^-0.5
    qh = qh * scale_fix
    out = _flash.flash_attention_bhsd(
        qh, kh, vh, causal=causal, window=window, cap=cap,
        block_q=block_q, block_kv=block_kv, interpret=_interpret())
    out = out.reshape(B, H, S, Dp).transpose(0, 2, 1, 3)
    return out[..., :D]


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@jax.jit
def gmm(x, w):
    """Grouped matmul (E,T,d)x(E,d,f)->(E,T,f) via the Pallas kernel."""
    return _gmm.gmm_pallas(x, w, interpret=_interpret())
