"""Byzantine-robust aggregation: pluggable robust reducers between the
per-client uploads and the global mean (DESIGN.md §12)."""
from repro.robust.reducers import (GATHER_MODES, ROBUST_MODES,
                                   RobustConfig, bucket_finish,
                                   bucket_partials, krum_weights,
                                   make_robust, masked_mean, pack_cohort,
                                   robust_reduce, trim_count,
                                   trimmed_reduce)

__all__ = [
    "GATHER_MODES",
    "ROBUST_MODES",
    "RobustConfig",
    "bucket_finish",
    "bucket_partials",
    "krum_weights",
    "make_robust",
    "masked_mean",
    "pack_cohort",
    "robust_reduce",
    "trim_count",
    "trimmed_reduce",
]
