"""Byzantine-robust aggregation reducers over the cohort upload stack.

The round engine's aggregate is a weighted mean over the (m, ...) upload
stack (strategies.resolve_mean); a finite-valued adversarial upload --
sign-flips, coordinated collusion, small-sigma perturbations -- passes
PR 7's screening (which only rejects non-finite values and oversized
norms) and poisons that mean.  This module supplies drop-in ROBUST
replacements for the mean, pure functions of ``(tree, w)`` where every
leaf has a leading cohort axis of size m and ``w`` is the (m,) screening
weight vector (1.0 for unscreened lanes):

  * ``trimmed`` -- per-COORDINATE sort; drop the f lowest and f highest
    values (f = round(frac * m)); weighted mean of the kept band.
  * ``median``  -- trimmed with f = (m-1)//2: the per-coordinate
    (weighted mid-)median.
  * ``krum``    -- Krum-lite geometric filtering: score each lane by its
    weighted squared distance to the whole cohort (one Gram matrix over
    the flattened uploads); keep the m-f closest-to-the-pack lanes and
    take their weighted mean.  Coordinate-wise attacks that hide inside
    per-coordinate order statistics still move the lane away from the
    pack in l2.
  * ``bucket``  -- bucketed robust mean: lanes pre-aggregate into B
    buckets (global lane g -> bucket g % B) by WEIGHTED partial sums,
    then a cheap robust reduce (median/trimmed) runs over the B bucket
    means.  The partial sums are linear, so under the mesh placement
    they ride the round's existing single psum -- O(1) cross-client
    data movement, no all-gather (engine._psum_mean_fn).

Screening composes: a screened lane enters with w=0 AND zero values
(faults.screen_upload), so it is massless in every weighted band/mask
here.  Zero-weight lanes do sit at value 0 inside the coordinate sorts
(they occupy trim-band slots without mass); under heavy drop rates
widen ``frac`` accordingly -- documented in DESIGN.md §12.

All reducer math is f32 regardless of the upload dtype (low-precision
``upload_dtype`` uploads are upcast exactly like the weighted-mean
path); reduced leaves come back f32, matching what the mesh psum path
has always handed the strategy's _axpy.

Collective budget per mode under the mesh placement (jaxpr-counted,
DESIGN.md §12):

    none              1 psum             (the bitwise default path)
    trimmed | median  1 all_gather + 1 psum
    krum              1 all_gather + 1 psum
    bucket            1 psum             (partials ride THE psum)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

ROBUST_MODES = ("trimmed", "median", "krum", "bucket")
# modes that need cross-client ORDER information: under the mesh
# placement they gather the full packed upload stack (one all_gather)
# and reduce it replicated-identically on every shard
GATHER_MODES = ("trimmed", "median", "krum")
_INNER_MODES = ("median", "trimmed")


@dataclass(frozen=True)
class RobustConfig:
    """One robust-aggregation spec.  ``frac`` is the per-tail trim
    fraction (trimmed / bucket-inner trimmed) or the filtered fraction
    (krum: keep m - round(frac*m) lanes); ``buckets``/``inner`` only
    apply to bucket mode."""

    mode: str
    frac: float = 0.25
    buckets: int = 4
    inner: str = "median"

    def __post_init__(self):
        if self.mode not in ROBUST_MODES:
            raise ValueError(
                f"robust mode {self.mode!r} not in {ROBUST_MODES}")
        if not 0.0 <= self.frac < 0.5:
            raise ValueError(
                f"robust frac must be in [0, 0.5), got {self.frac}")
        if self.buckets < 2:
            raise ValueError(
                f"robust buckets must be >= 2, got {self.buckets}")
        if self.inner not in _INNER_MODES:
            raise ValueError(
                f"robust inner mode {self.inner!r} not in {_INNER_MODES}")

    @property
    def gathers(self) -> bool:
        """True when the mesh lowering needs the one all_gather."""
        return self.mode in GATHER_MODES

    @property
    def spec(self) -> str:
        """Canonical spec string (parse . spec == identity): what goes
        into checkpoint meta and bench config rows."""
        d = RobustConfig("median")
        if self.mode == "median":
            return "median"
        if self.mode in ("trimmed", "krum"):
            return f"{self.mode}:{self.frac:g}"
        s = f"bucket:{self.buckets}"
        if self.inner != d.inner:
            s += f",inner:{self.inner}"
        if self.inner == "trimmed" and self.frac != d.frac:
            s += f",frac:{self.frac:g}"
        return s

    def check_cohort(self, m: int) -> None:
        """Static feasibility vs the cohort size (mirrors
        MeshPlacement.check): the trim band / kept set must be
        non-empty."""
        if self.mode == "trimmed" and 2 * trim_count(self.frac, m) >= m:
            raise ValueError(
                f"robust trimmed:{self.frac:g} trims "
                f"{2 * trim_count(self.frac, m)} of m={m} lanes; "
                "lower frac or enlarge the cohort")
        if self.mode == "krum" and m - trim_count(self.frac, m) < 1:
            raise ValueError(
                f"robust krum:{self.frac:g} keeps no lanes at m={m}")
        if self.mode == "bucket" and self.buckets > m:
            raise ValueError(
                f"robust bucket:{self.buckets} exceeds the cohort size "
                f"m={m}: empty buckets would dilute the inner reduce")


def make_robust(spec) -> RobustConfig | None:
    """Parse a ``--robust`` spec string into a RobustConfig.

    Grammar: ``none`` | ``median`` | ``trimmed[:F]`` | ``krum[:F]`` |
    ``bucket[:B][,inner:median|trimmed][,frac:F]``.  None/''/'none'
    return None -- the engine's bitwise no-robust fast path (mirrors
    ``make_faults`` normalizing inactive configs).  A RobustConfig
    passes through unchanged."""
    if spec is None or isinstance(spec, RobustConfig):
        return spec
    spec = spec.strip()
    if spec in ("", "none"):
        return None
    from repro.configs.specs import cast_value, parse_spec
    p = parse_spec(
        spec, flag="--robust",
        heads=("none",) + ROBUST_MODES,
        arity={"trimmed": (0, 1), "krum": (0, 1), "bucket": (0, 1)},
        keys={"bucket": ("inner", "frac")},
        head_label="mode",
        key_hint="only bucket mode takes inner:MODE and frac:F")
    if p.head == "none":
        return None
    kw = {}
    if p.args:
        if p.head in ("trimmed", "krum"):
            kw["frac"] = cast_value("--robust", p.head, p.args[0], float)
        else:  # bucket
            kw["buckets"] = cast_value("--robust", p.head, p.args[0], int)
    for k, v in p.kv:
        kw[k] = v if k == "inner" else \
            cast_value("--robust", k, v, float)
    return RobustConfig(p.head, **kw)


def trim_count(frac: float, m: int) -> int:
    """Lanes trimmed per tail (trimmed) / filtered in total (krum)."""
    return int(round(frac * m))


# ---------------------------------------------------------------------------
# the reducers: pure (tree, w) -> tree functions
# ---------------------------------------------------------------------------

def _trimmed_leaf(t: jax.Array, w: jax.Array, f_lo: int,
                  f_hi: int) -> jax.Array:
    """Weighted trimmed mean of one (m, ...) leaf: per-coordinate value
    sort, the weights permuted INTO value order alongside, keep the band
    [f_lo : m - f_hi], weighted mean over the band.  Zero band mass
    (every kept lane screened) falls back to the band's uniform mean --
    the kept values are then all zero-valued screened lanes, so the
    fallback matches the psum path's zero-delta degradation."""
    m = t.shape[0]
    v = t.astype(jnp.float32).reshape(m, -1)  # (m, d)
    order = jnp.argsort(v, axis=0)
    vs = jnp.take_along_axis(v, order, axis=0)
    ws = jnp.take_along_axis(
        jnp.broadcast_to(w.astype(jnp.float32)[:, None], v.shape),
        order, axis=0)
    vk, wk = vs[f_lo:m - f_hi], ws[f_lo:m - f_hi]
    tot = wk.sum(axis=0)  # (d,) -- band mass varies per coordinate
    num = (wk * vk).sum(axis=0)
    out = jnp.where(tot > 0, num / jnp.where(tot > 0, tot, 1.0),
                    vk.mean(axis=0))
    return out.reshape(t.shape[1:])


def _tail_counts(cfg: RobustConfig, m: int, inner: bool = False) -> int:
    mode = cfg.inner if inner else cfg.mode
    if mode == "median":
        return (m - 1) // 2
    return trim_count(cfg.frac, m)


def trimmed_reduce(cfg: RobustConfig, tree: Pytree,
                   w: jax.Array) -> Pytree:
    """trimmed / median over the full (m, ...) stack."""
    m = w.shape[0]
    f = _tail_counts(cfg, m)
    return jax.tree.map(lambda t: _trimmed_leaf(t, w, f, f), tree)


def krum_weights(cfg: RobustConfig, tree: Pytree,
                 w: jax.Array) -> jax.Array:
    """Krum-lite lane mask * screening weights: one (m, m) Gram matrix
    over the flattened uploads gives every pairwise squared distance;
    lane i's score is its WEIGHTED distance to the whole cohort
    (screened lanes exert no pull and score +inf so they are never
    kept); the m - f smallest scores survive."""
    m = w.shape[0]
    keep = max(m - trim_count(cfg.frac, m), 1)
    g = jnp.zeros((m, m), jnp.float32)
    for t in jax.tree.leaves(tree):
        v = t.astype(jnp.float32).reshape(m, -1)
        g = g + v @ v.T
    sq = jnp.diagonal(g)
    d2 = sq[:, None] + sq[None, :] - 2.0 * g
    wf = w.astype(jnp.float32)
    score = (d2 * wf[None, :]).sum(axis=1)
    score = jnp.where(wf > 0, score, jnp.inf)
    _, idx = jax.lax.top_k(-score, keep)
    mask = jnp.zeros((m,), jnp.float32).at[idx].set(1.0)
    return mask * wf


def masked_mean(tree: Pytree, wm: jax.Array) -> Pytree:
    """Weighted mean over the stack under combined weights ``wm``; zero
    total mass falls back to the uniform mean (all-screened cohorts are
    all-zero-valued, so this degrades to the psum path's zero delta)."""
    tot = wm.sum()
    safe = jnp.where(tot > 0, tot, 1.0)
    return jax.tree.map(
        lambda t: jnp.where(
            tot > 0,
            jnp.tensordot(wm, t.astype(jnp.float32), axes=(0, 0)) / safe,
            t.astype(jnp.float32).mean(axis=0)),
        tree)


def bucket_partials(cfg: RobustConfig, tree: Pytree, w: jax.Array,
                    lane0) -> Tuple[Pytree, jax.Array]:
    """Per-bucket WEIGHTED partial sums over the local lanes: global
    lane g = lane0 + local index lands in bucket g % B.  Returns
    ``(sums, wsum)`` with a leading (B,) axis -- both LINEAR in the
    lanes, which is exactly why the mesh lowering can psum them inside
    the round's one collective (``lane0 = axis_index * m_local`` keeps
    the global bucket assignment identical to the vmap path)."""
    m_local = w.shape[0]
    b = jnp.mod(lane0 + jnp.arange(m_local), cfg.buckets)
    wf = w.astype(jnp.float32)
    wsum = jnp.zeros((cfg.buckets,), jnp.float32).at[b].add(wf)
    sums = jax.tree.map(
        lambda t: jnp.zeros((cfg.buckets,) + t.shape[1:], jnp.float32)
        .at[b].add(wf.reshape((m_local,) + (1,) * (t.ndim - 1))
                   * t.astype(jnp.float32)),
        tree)
    return sums, wsum


def bucket_finish(cfg: RobustConfig, sums: Pytree,
                  wsum: jax.Array) -> Pytree:
    """Bucket means + the inner robust reduce over the B (replicated)
    buckets, with the bucket masses as the inner weights: an empty
    bucket is a zero-valued zero-mass row, exactly a screened lane one
    level up."""
    f = _tail_counts(cfg, cfg.buckets, inner=True)
    safe = jnp.where(wsum > 0, wsum, 1.0)
    return jax.tree.map(
        lambda s: _trimmed_leaf(
            s / safe.reshape((cfg.buckets,) + (1,) * (s.ndim - 1)),
            wsum, f, f),
        sums)


def robust_reduce(cfg: RobustConfig, tree: Pytree,
                  w: jax.Array) -> Pytree:
    """The full-stack robust reduce: dispatch on mode.  ``tree`` leaves
    carry the (m, ...) cohort axis, ``w`` is the (m,) screening weight
    vector (ones when nothing screens).  Single-device semantics; the
    mesh placement reassembles the same full stack from its shards
    first (engine._psum_mean_fn), so both placements run THIS math."""
    if cfg.mode in ("trimmed", "median"):
        return trimmed_reduce(cfg, tree, w)
    if cfg.mode == "krum":
        return masked_mean(tree, krum_weights(cfg, tree, w))
    sums, wsum = bucket_partials(cfg, tree, w, 0)
    return bucket_finish(cfg, sums, wsum)


# ---------------------------------------------------------------------------
# mesh packing: ONE all_gather for the whole upload stack
# ---------------------------------------------------------------------------

def pack_cohort(tree: Pytree, w: jax.Array) -> Tuple[jax.Array, Callable]:
    """Flatten the (m_local, ...) upload stack + per-lane weights into
    ONE f32 (m_local, D+1) buffer.  ``jax.lax.all_gather`` emits one
    primitive PER LEAF when handed a pytree; packing first keeps the
    mesh gather modes at exactly one all_gather in the jaxpr -- the
    declared collective budget -- mirroring how the psum path bundles
    its operands into one collective.  Returns ``(buf, unpack)`` where
    ``unpack(full)`` splits a gathered (m, D+1) buffer back into the
    full-cohort (tree, w)."""
    leaves, treedef = jax.tree.flatten(tree)
    m = w.shape[0]
    shapes = [t.shape[1:] for t in leaves]
    buf = jnp.concatenate(
        [t.astype(jnp.float32).reshape(m, -1) for t in leaves]
        + [w.astype(jnp.float32)[:, None]], axis=1)

    def unpack(full: jax.Array):
        out, o = [], 0
        for s in shapes:
            d = 1
            for n in s:
                d *= n
            out.append(full[:, o:o + d].reshape((full.shape[0],) + s))
            o += d
        return jax.tree.unflatten(treedef, out), full[:, o]

    return buf, unpack
