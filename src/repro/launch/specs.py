"""ShapeDtypeStruct input specs + shardings for every (arch x shape x mesh).

No device allocation happens here: params/caches come from jax.eval_shape
over the real init functions, so the dry-run lowers exactly the structures
the runtime would use.  Modality frontends ([audio]/[vlm] carve-out) appear
as embedding inputs of the right shape."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ArchConfig
from repro.launch.mesh import MeshRoles, mesh_roles
from repro.models import transformer
from repro.sharding import rules

Pytree = Any


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


@dataclass
class StepSpec:
    """Everything jit needs: arg structs + in/out shardings + callable."""
    kind: str                      # 'train' | 'prefill' | 'decode'
    args: tuple                    # ShapeDtypeStructs (pytrees)
    in_shardings: tuple
    fn: Any                        # the step callable to jit
    meta: Dict[str, Any]


def _frontend_spec(cfg, lead_dims, dtype):
    return sds((*lead_dims, cfg.frontend_tokens, cfg.d_model), dtype)


def _batch_struct(cfg, lead_dims, seq, dtype):
    b: Dict[str, Any] = {
        "tokens": sds((*lead_dims, seq), jnp.int32),
        "labels": sds((*lead_dims, seq), jnp.int32),
    }
    if cfg.frontend is not None:
        b["frontend"] = _frontend_spec(cfg, lead_dims, dtype)
    return b


def _replicate(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def make_train_spec(cfg: ArchConfig, shape_name: str, mesh, *,
                    strategy=None, tau: int = 4, dtype=jnp.bfloat16,
                    remat: bool = False, chunkwise: bool = True,
                    unroll=1, b_local: int = 0) -> StepSpec:
    """One FedDeper round step (the paper's technique) on the mesh.

    ``tau`` is the number of scanned local steps actually LOWERED;
    ``b_local`` (per-client per-step microbatch) may be pinned so two
    lowerings with different tau have identical scan bodies (the dry-run
    differencing trick)."""
    from repro.core import FedDeper, make_round_step
    ishape = INPUT_SHAPES[shape_name]
    roles = mesh_roles(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    C = sizes[roles.client]
    strategy = strategy or FedDeper(eta=1e-2, rho=1e-3, lam=0.5)
    b_local = b_local or max(1, ishape.global_batch // (C * tau))

    params = transformer.param_shapes(cfg, dtype)
    x_shard = rules.param_specs(params, mesh, model=roles.model,
                                fsdp=roles.fsdp)
    client_state = jax.eval_shape(
        lambda p: jax.tree.map(
            lambda l: jnp.zeros((C,) + l.shape, l.dtype),
            strategy.client_init(p)), params)
    cs_shard = rules.param_specs(client_state, mesh, model=roles.model,
                                 fsdp=roles.fsdp, client=roles.client)
    server_state = jax.eval_shape(strategy.server_init, params)
    ss_shard = rules.param_specs(server_state, mesh, model=roles.model,
                                 fsdp=roles.fsdp)

    batch = _batch_struct(cfg, (C, tau, b_local), ishape.seq_len, dtype)
    bspec = rules.train_batch_spec(mesh, client=roles.client,
                                   fsdp=roles.fsdp)
    b_shard = jax.tree.map(
        lambda l: NamedSharding(mesh, bspec(len(l.shape))), batch)

    fn = make_round_step(cfg, strategy, chunkwise=chunkwise, remat=remat,
                         unroll=unroll)
    return StepSpec(
        kind="train",
        args=(params, server_state, client_state, batch),
        in_shardings=(x_shard, ss_shard, cs_shard, b_shard),
        fn=fn,
        meta={"clients": C, "tau": tau, "b_local": b_local,
              "tokens_per_round": C * tau * b_local * ishape.seq_len},
    )


def make_sync_spec(cfg: ArchConfig, shape_name: str, mesh, *,
                   dtype=jnp.bfloat16, remat: bool = False,
                   chunkwise: bool = True, unroll=1) -> StepSpec:
    """Synchronous data-parallel SGD baseline (= FedAvg tau=1)."""
    from repro.core import make_sync_train_step
    ishape = INPUT_SHAPES[shape_name]
    roles = mesh_roles(mesh)
    params = transformer.param_shapes(cfg, dtype)
    x_shard = rules.param_specs(params, mesh, model=roles.model,
                                fsdp=roles.fsdp)
    batch = _batch_struct(cfg, (ishape.global_batch,), ishape.seq_len, dtype)
    b_shard = jax.tree.map(
        lambda l: NamedSharding(mesh, P(roles.dp, *([None] *
                                                    (len(l.shape) - 1)))),
        batch)
    fn = make_sync_train_step(cfg, chunkwise=chunkwise, remat=remat,
                              unroll=unroll)
    return StepSpec(kind="train", args=(params, batch),
                    in_shardings=(x_shard, b_shard), fn=fn,
                    meta={"tokens_per_step":
                          ishape.global_batch * ishape.seq_len})


def make_serve_spec(cfg: ArchConfig, shape_name: str, mesh, *,
                    dtype=jnp.bfloat16, chunkwise: bool = True,
                    unroll=1, param_fsdp: bool = False,
                    seq_shard_decode: bool = False) -> StepSpec:
    """prefill_32k lowers prefill; decode shapes lower one serve_step
    (one new token against a seq_len-deep cache).

    ``param_fsdp``: additionally shard serve params over the data axes
    (ZeRO-style) -- required for >100B archs to fit HBM at serve time."""
    from repro.core import make_decode_step, make_prefill_step
    ishape = INPUT_SHAPES[shape_name]
    roles = mesh_roles(mesh)
    B, S = ishape.global_batch, ishape.seq_len
    params = transformer.param_shapes(cfg, dtype)
    fsdp = (roles.fsdp or "data") if param_fsdp else roles.fsdp
    x_shard = rules.param_specs(params, mesh, model=roles.model,
                                fsdp=fsdp)
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S, dtype))
    c_shard = rules.cache_specs(cache, mesh, model=roles.model,
                                dp=roles.dp, prefer_seq=seq_shard_decode)

    if ishape.mode == "prefill":
        # the context budget includes the VLM patch prefix: text tokens
        # fill the rest of the window
        text_len = S - (cfg.frontend_tokens
                        if (cfg.frontend and not cfg.is_encdec) else 0)
        batch = {"tokens": sds((B, text_len), jnp.int32)}
        if cfg.frontend is not None:
            batch["frontend"] = _frontend_spec(cfg, (B,), dtype)
        dp = roles.dp
        b_shard = jax.tree.map(
            lambda l: NamedSharding(
                mesh, P(dp if l.shape[0] % _n(mesh, dp) == 0 else None,
                        *([None] * (len(l.shape) - 1)))), batch)
        fn = make_prefill_step(cfg, chunkwise=chunkwise, unroll=unroll)
        return StepSpec(kind="prefill", args=(params, batch, cache),
                        in_shardings=(x_shard, b_shard, c_shard), fn=fn,
                        meta={"batch": B, "seq": S})

    tokens = sds((B, 1), jnp.int32)
    dp_ok = B % _n(mesh, roles.dp) == 0
    t_shard = NamedSharding(
        mesh, P(roles.dp if dp_ok else None, None))
    pos = sds((), jnp.int32)
    seq_shard = None
    if seq_shard_decode:
        seq_shard = {"axis": roles.model,
                     "dp": roles.dp if dp_ok else (), "mesh": mesh}
    fn = make_decode_step(cfg, chunkwise=chunkwise, unroll=unroll,
                          seq_shard=seq_shard)
    return StepSpec(kind="decode", args=(params, cache, tokens, pos),
                    in_shardings=(x_shard, c_shard, t_shard,
                                  NamedSharding(mesh, P())),
                    fn=fn, meta={"batch": B, "cache_len": S})


def _n(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(math.prod(sizes[a] for a in axes))


def make_step_spec(cfg, shape_name, mesh, *, variant: str = "feddeper",
                   tau: int = 4, remat: bool = False,
                   dtype=jnp.bfloat16, chunkwise: bool = True,
                   strategy=None, unroll=1, b_local: int = 0,
                   param_fsdp: bool = False,
                   seq_shard_decode: bool = False) -> StepSpec:
    mode = INPUT_SHAPES[shape_name].mode
    if mode == "train":
        if variant == "sync":
            return make_sync_spec(cfg, shape_name, mesh, dtype=dtype,
                                  remat=remat, chunkwise=chunkwise,
                                  unroll=unroll)
        return make_train_spec(cfg, shape_name, mesh, strategy=strategy,
                               tau=tau, dtype=dtype, remat=remat,
                               chunkwise=chunkwise, unroll=unroll,
                               b_local=b_local)
    return make_serve_spec(cfg, shape_name, mesh, dtype=dtype,
                           chunkwise=chunkwise, unroll=unroll,
                           param_fsdp=param_fsdp,
                           seq_shard_decode=seq_shard_decode)
