# NOTE: do not import dryrun here -- it sets XLA_FLAGS at import time and
# must only be imported as __main__ (python -m repro.launch.dryrun).
from repro.launch.mesh import (  # noqa: F401
    make_client_mesh,
    make_production_mesh,
    make_smoke_mesh,
    mesh_roles,
)
