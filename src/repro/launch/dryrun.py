import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh).

The two lines above MUST stay the first statements: jax locks the device
count on first init, and the dry-run needs 512 host placeholder devices to
build the production meshes.  (Tests/benchmarks never import this module.)

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out experiments/dryrun.jsonl
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config
from repro.launch import corrections, hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_step_spec
from repro.models import transformer


def analytic_bytes_for(cfg, spec_kind: str, meta: dict, variant: str,
                       tau: int, chips: int, shape) -> float:
    """First-principles per-device HBM traffic (bf16).  XLA's
    'bytes accessed' counts every operand of every op (no fusion), so it
    overestimates; this analytic floor counts weight passes, activation
    fwd/bwd traffic and cache reads -- the roofline narrative reports both.
    """
    n = transformer.active_param_count(cfg)
    model_shard = 16  # model axis size on both meshes
    p_loc = 2.0 * n / model_shard  # bf16 param bytes per device
    d, L = cfg.d_model, cfg.num_layers
    if spec_kind == "train":
        tokens_loc = meta.get("tokens_per_round",
                              meta.get("tokens_per_step", 0)) / chips
        streams = 2 if variant == "feddeper" else 1
        weight_passes = 3 * streams * (tau if variant == "feddeper" else 1) \
            + 4 * streams
        act = tokens_loc * L * d * 16 * 2 * streams  # fwd store + bwd read
        return weight_passes * p_loc + act
    if spec_kind == "prefill":
        tokens_loc = meta["batch"] * meta["seq"] / chips
        return p_loc + tokens_loc * L * d * 8 * 2
    # decode: weights once + full cache read
    kv = (cfg.kv_lora_rank + cfg.qk_rope_dim) if cfg.use_mla else \
        2 * cfg.num_kv_heads * cfg.resolved_head_dim
    n_attn = sum(1 for s in (list(cfg.prefix)
                             + list(cfg.pattern) * cfg.num_repeats)
                 if s.kind == "attn")
    cache = meta["batch"] * meta["cache_len"] * kv * 2.0 * n_attn / chips
    return p_loc + cache


def model_flops_for(cfg, spec_kind: str, meta: dict, variant: str) -> float:
    """MODEL_FLOPS: 6*N_active*D train / 2*N_active*D inference (global)."""
    n_active = transformer.active_param_count(cfg)
    if spec_kind == "train":
        tokens = meta.get("tokens_per_round", meta.get("tokens_per_step", 0))
        passes = 2.0 if variant == "feddeper" else 1.0  # y and v grads
        return 6.0 * n_active * tokens * passes
    if spec_kind == "prefill":
        return 2.0 * n_active * meta["batch"] * meta["seq"]
    return 2.0 * n_active * meta["batch"]  # decode: one token per row


def _compile_and_measure(spec, mesh):
    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = jax.jit(spec.fn,
                          in_shardings=spec.in_shardings).lower(*spec.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost_list = compiled.cost_analysis()
        cost = cost_list[0] if isinstance(cost_list, (list, tuple)) \
            else cost_list
        flops = hlo_analysis.cost_entry(cost, "flops")
        byts = hlo_analysis.cost_entry(cost, "bytes accessed")
        colls = hlo_analysis.parse_collectives(compiled.as_text())
        mem = hlo_analysis.memory_summary(compiled)
    return {"flops": flops, "bytes": byts, "coll": colls.total_bytes,
            "coll_counts": colls.counts, "coll_by_op": colls.bytes_by_op,
            "mem": mem, "lower_s": t_lower, "compile_s": t_compile}


def run_one(arch: str, shape: str, *, multi_pod: bool,
            variant: str = "feddeper", tau: int = 4, remat: bool = False,
            chunkwise: bool = True, dtype=jnp.bfloat16,
            unroll_layers: bool = True, param_fsdp: bool = False,
            seq_shard_decode: bool = False, upload_dtype: str = "",
            tag: str = "") -> dict:
    cfg = get_config(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if shape not in cfg.shapes():
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "skipped",
                "reason": "full-attention arch: long_500k documented skip"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    unroll = True if unroll_layers else 1
    kind = INPUT_SHAPES[shape].mode
    common = dict(variant=variant, remat=remat, chunkwise=chunkwise,
                  dtype=dtype, unroll=unroll, param_fsdp=param_fsdp,
                  seq_shard_decode=seq_shard_decode)
    if upload_dtype and kind == "train" and variant != "sync":
        from repro.core import FedDeper
        common["strategy"] = FedDeper(eta=1e-2, rho=1e-3, lam=0.5,
                                      upload_dtype=upload_dtype)

    if kind == "train" and variant != "sync":
        # The tau (local-step) scan stays rolled for compile speed, so the
        # HLO cost model counts its body ONCE.  Reconstruct the true round
        # cost from two compiles: the full round (= agg + 1 body) and the
        # aggregation alone (tiny, elementwise).  Then
        #     round(tau) = agg + tau * (full - agg).
        # The per-round (non-scanned) client ops (mixing, upload) get
        # multiplied too -- a documented ~1/tau-param-pass overcount.
        spec = make_step_spec(cfg, shape, mesh, tau=tau, **common)
        m_full = _compile_and_measure(spec, mesh)

        from repro.core import FedDeper
        strat = common.get("strategy") or FedDeper(eta=1e-2, rho=1e-3,
                                                   lam=0.5)
        x_sh, ss_sh, cs_sh, _ = spec.in_shardings
        x_arg, ss_arg, cs_arg, _ = spec.args
        up_dt = jnp.dtype(strat.upload_dtype) \
            if getattr(strat, "upload_dtype", "") else None
        uploads = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, up_dt or l.dtype),
            cs_arg["v"])  # (C, ...) shaped

        def agg_only(x, ss, up):
            new_x, new_ss, _ = strat.aggregate(x, ss, up, p=1.0)
            return new_x, new_ss

        agg_spec = type(spec)(
            kind="train", args=(x_arg, ss_arg, uploads),
            in_shardings=(x_sh, ss_sh, cs_sh["v"]), fn=agg_only, meta={})
        m_agg = _compile_and_measure(agg_spec, mesh)
        synth = {k: m_agg[k] + tau * max(0.0, m_full[k] - m_agg[k])
                 for k in ("flops", "bytes", "coll")}
        meta = dict(spec.meta)
        measured = {**synth,
                    "coll_counts": m_full["coll_counts"],
                    "coll_by_op": m_full["coll_by_op"],
                    "mem": m_full["mem"],
                    "lower_s": m_full["lower_s"] + m_agg["lower_s"],
                    "compile_s": m_full["compile_s"] + m_agg["compile_s"]}
        spec_kind = "train"
    else:
        spec = make_step_spec(cfg, shape, mesh, tau=tau, **common)
        measured = _compile_and_measure(spec, mesh)
        meta = spec.meta
        spec_kind = spec.kind

    ishape = INPUT_SHAPES[shape]
    if spec_kind == "train":
        if variant == "sync":
            corr_B, corr_tau = ishape.global_batch, 1
        else:
            corr_B = meta["clients"] * meta["b_local"]
            corr_tau = tau
        corr = corrections.correction_for(
            cfg, spec_kind, B=corr_B, S=ishape.seq_len, variant=variant,
            tau=corr_tau, chips=chips)
    elif spec_kind == "prefill":
        corr = corrections.correction_for(
            cfg, spec_kind, B=ishape.global_batch, S=ishape.seq_len,
            chips=chips)
    else:
        corr = corrections.Correction()
    flops = measured["flops"] + corr.flops
    byts = measured["bytes"] + corr.bytes
    coll = measured["coll"]
    mflops = model_flops_for(cfg, spec_kind, meta, variant)
    abytes = analytic_bytes_for(cfg, spec_kind, meta, variant, tau, chips,
                                shape)
    compute_s = flops / hlo_analysis.PEAK_FLOPS
    memory_s = byts / hlo_analysis.HBM_BW
    coll_s = coll / hlo_analysis.ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    return {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "variant": variant, "kind": spec_kind, "status": "ok",
        "tag": tag, "param_fsdp": param_fsdp,
        "seq_shard_decode": seq_shard_decode,
        "unroll_layers": unroll_layers,
        "chips": chips, "tau": tau, "remat": remat,
        "lower_s": round(measured["lower_s"], 1),
        "compile_s": round(measured["compile_s"], 1),
        "memory": measured["mem"], "meta": meta,
        "params": transformer.param_count(cfg),
        "active_params": transformer.active_param_count(cfg),
        "flops_per_device": flops,
        "hlo_flops_raw": measured["flops"],
        "scan_correction_flops": corr.flops,
        "bytes_per_device": byts,
        "analytic_bytes_per_device": abytes,
        "analytic_memory_s": abytes / hlo_analysis.HBM_BW,
        "collective_bytes_per_device": coll,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / (flops * chips)) if flops else 0.0,
        "collective_counts": measured["coll_counts"],
        "collective_bytes_by_op": measured["coll_by_op"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="train_4k",
                    help="input shape or 'all'")
    ap.add_argument("--mesh", default="pod1",
                    choices=["pod1", "pod2", "both"])
    ap.add_argument("--variant", default="feddeper",
                    choices=["feddeper", "sync"])
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--no-chunkwise", action="store_true",
                    help="xLSTM: recurrent instead of chunkwise mLSTM")
    ap.add_argument("--serve-fsdp", action="store_true",
                    help="shard serve params over the data axes too")
    ap.add_argument("--seq-decode", action="store_true",
                    help="shard_map flash-decode over seq-sharded caches")
    ap.add_argument("--upload-dtype", default="",
                    help="FedDeper delta upload dtype (e.g. float8_e4m3fn)")
    ap.add_argument("--rolled", action="store_true",
                    help="keep the layer scan rolled (fast compile; "
                         "HLO flops undercount layers -- use model_flops)")
    ap.add_argument("--tag", default="", help="label for perf iterations")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    archs = list(ALL_ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod1": [False], "pod2": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_one(arch, shape, multi_pod=mp,
                                  variant=args.variant, tau=args.tau,
                                  remat=args.remat,
                                  chunkwise=not args.no_chunkwise,
                                  param_fsdp=args.serve_fsdp,
                                  seq_shard_decode=args.seq_decode,
                                  upload_dtype=args.upload_dtype,
                                  unroll_layers=not args.rolled,
                                  tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc(limit=8)}
                    failures += 1
                line = json.dumps(rec)
                print(line, flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
