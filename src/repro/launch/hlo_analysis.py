"""Roofline-term extraction from compiled (post-SPMD) HLO.

``compiled.cost_analysis()`` supplies per-device HLO FLOPs / bytes.
Collective bytes are NOT in cost_analysis: we parse ``compiled.as_text()``
(the per-device SPMD module, shapes already shard-local) and sum operand
sizes over every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute with ring-algorithm multipliers:

    all-gather         result_bytes * (n-1)/n
    all-reduce         result_bytes * 2(n-1)/n
    reduce-scatter     result_bytes * (n-1)        (input = result * n)
    all-to-all         result_bytes * (n-1)/n
    collective-permute result_bytes

where n = participating group size parsed from replica_groups.  The
collective roofline term is per-device bytes / link bandwidth.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"^[ \t]*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(?P<type>\([^)]*\)|[\w\[\],{}]+)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(",
    re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    return 2  # conservative default


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    total_bytes: float = 0.0  # per-device bytes moved over ICI


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        eol = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():eol if eol != -1 else len(hlo_text)]
        size = _shape_bytes(m.group("type"))
        n = _group_size(line)
        if n <= 1:
            continue
        if op == "all-gather":
            moved = size * (n - 1) / n
        elif op == "all-reduce":
            moved = size * 2 * (n - 1) / n
        elif op == "reduce-scatter":
            moved = size * (n - 1)
        elif op == "all-to-all":
            moved = size * (n - 1) / n
        else:  # collective-permute
            moved = size
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + moved
        stats.total_bytes += moved
    return stats


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_flops_ratio: float = 0.0
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes_by_op: Dict[str, float] = field(default_factory=dict)

    def to_dict(self):
        return asdict(self)


def cost_entry(cost: dict, *names: str) -> float:
    for n in names:
        if n in cost:
            return float(cost[n])
    return 0.0


def roofline_from(compiled, *, chips: int,
                  model_flops_total: float = 0.0) -> Roofline:
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    flops = cost_entry(cost, "flops")
    byts = cost_entry(cost, "bytes accessed", "bytes accessedout", "bytes")
    stats = parse_collectives(compiled.as_text())
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = stats.total_bytes / ICI_BW
    dom = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", coll_s)), key=lambda kv: kv[1])[0]
    useful = 0.0
    if model_flops_total and flops:
        useful = model_flops_total / (flops * chips)
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=stats.total_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dom,
        model_flops=model_flops_total,
        useful_flops_ratio=useful,
        collective_counts=stats.counts,
        collective_bytes_by_op=stats.bytes_by_op,
    )


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, name, None)
        if v is not None:
            out[name] = float(v)
    if not out and isinstance(ma, dict):
        out = {k: float(v) for k, v in ma.items()}
    return out
