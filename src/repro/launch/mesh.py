"""Mesh construction for the production dry-run target.

TPU v5e: 16x16 = 256 chips per pod; multi-pod = 2 pods = 512 chips.
Functions, not module constants -- importing this module never touches jax
device state (device count is locked on first jax init)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax

from repro.compat import axis_types_auto, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=axis_types_auto(len(axes)))


def make_smoke_mesh():
    """1-device mesh with production axis names (CPU tests)."""
    return make_mesh((1, 1), ("data", "model"),
                     axis_types=axis_types_auto(2))


def make_client_mesh(n_devices: Optional[int] = None):
    """Every local device on the client ('data') axis, model axis 1: the
    mesh the cohort engine's mesh placement targets by default.  On the
    CPU container this is a 1-device mesh unless the process was started
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` (the
    multi-device CI emulation; collectives become host memcpys, so only
    layouts and collective counts are representative, not timings)."""
    n = jax.local_device_count() if n_devices is None else n_devices
    return make_mesh((n, 1), ("data", "model"),
                     axis_types=axis_types_auto(2))


@dataclass(frozen=True)
class MeshRoles:
    """Which mesh axes play which FL/parallelism role."""
    client: str            # FL client axis (cross-client sync axis)
    model: str             # tensor/expert-parallel axis
    fsdp: Optional[str]    # intra-client param sharding axis (multi-pod)
    dp: Tuple[str, ...]    # data-parallel axes for serving batch dims


def mesh_roles(mesh) -> MeshRoles:
    names = mesh.axis_names
    if "pod" in names:
        return MeshRoles(client="pod", model="model", fsdp="data",
                         dp=("pod", "data"))
    return MeshRoles(client="data", model="model", fsdp=None, dp=("data",))


def num_clients(mesh) -> int:
    roles = mesh_roles(mesh)
    return dict(zip(mesh.axis_names, mesh.devices.shape))[roles.client]
