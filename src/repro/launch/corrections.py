"""Analytic corrections for costs XLA's HLO cost model hides inside loops.

``cost_analysis`` counts a while-loop body ONCE regardless of trip count
(verified empirically).  The dry-run removes the big undercounts
structurally -- the layer scan is lowered with ``unroll=True`` and the tau
(microbatch) scan is recovered exactly by differencing tau=1 vs tau=2
compiles -- but three inner loops remain rolled for compile-time sanity and
are corrected here from first principles:

  * chunked attention: lax.map over nq q-chunks x lax.scan over nk
    kv-chunks counts 1 of nq*nk bodies;
  * chunkwise mLSTM: scan over nC chunks counts 1;
  * sLSTM: scan over S time steps counts 1.

All corrections are *as-executed* costs (the chunked path computes masked
blocks too), per ONE forward pass, global across chips; the driver scales
by AD factor (fwd=1 / train fwd+bwd=3), FedDeper's 2 gradient streams, tau,
and divides by chip count.  Bytes corrections count block operand traffic
(f32 accumulators, input-dtype streams).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ArchConfig

BYTES_IN = 2  # bf16 streams


@dataclass
class Correction:
    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, o):
        return Correction(self.flops + o.flops, self.bytes + o.bytes)

    def scale(self, f: float):
        return Correction(self.flops * f, self.bytes * f)


def _attn_layer(cfg: ArchConfig, B: int, S: int, q_chunk: int,
                kv_chunk: int) -> Correction:
    """One attention layer forward, chunked online-softmax path."""
    H = cfg.num_heads
    if cfg.use_mla:
        d_qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        d_v = cfg.v_head_dim
    else:
        d_qk = d_v = cfg.resolved_head_dim
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    nq, nk = S // qc, S // kc
    body_flops = 2.0 * B * H * qc * kc * (d_qk + d_v)
    body_bytes = B * (qc * H * d_qk + kc * cfg.num_kv_heads *
                      (d_qk + d_v)) * BYTES_IN + B * qc * H * d_v * 4
    missing = nq * nk - 1
    return Correction(body_flops * missing, body_bytes * missing)


def _mlstm_layer(cfg: ArchConfig, B: int, S: int, chunk: int) -> Correction:
    di = cfg.mlstm_expand * cfg.d_model
    H = cfg.num_heads
    dh = di // H
    L = min(chunk, S)
    nC = max(1, S // L)
    # intra-chunk quadratic (qk + pv) + inter-chunk state update/apply
    body_flops = B * H * (4.0 * L * L * dh + 6.0 * L * dh * dh)
    body_bytes = B * H * (3 * L * dh * BYTES_IN + dh * dh * 4)
    missing = nC - 1
    return Correction(body_flops * missing, body_bytes * missing)


def _slstm_layer(cfg: ArchConfig, B: int, S: int) -> Correction:
    d = cfg.d_model
    dh = d // cfg.num_heads
    body_flops = 2.0 * B * d * 4 * dh + 40.0 * B * d  # recurrent + gates
    body_bytes = B * d * 4 * 6  # f32 state reads/writes
    missing = S - 1
    return Correction(body_flops * missing, body_bytes * missing)


def _layer_list(cfg: ArchConfig):
    layers = list(cfg.prefix) + list(cfg.pattern) * cfg.num_repeats
    return layers


def forward_correction(cfg: ArchConfig, *, B: int, S: int,
                       q_chunk: int = 512, kv_chunk: int = 1024,
                       mlstm_chunk: int = 256,
                       include_encoder: bool = False,
                       enc_B: int = 0, enc_S: int = 0) -> Correction:
    """Correction for ONE forward pass over (B, S) tokens (global)."""
    total = Correction()
    for spec in _layer_list(cfg):
        if spec.kind == "attn":
            total = total + _attn_layer(cfg, B, S, q_chunk, kv_chunk)
        elif spec.kind == "mlstm":
            total = total + _mlstm_layer(cfg, B, S, mlstm_chunk)
        elif spec.kind == "slstm":
            total = total + _slstm_layer(cfg, B, S)
        # mamba: associative_scan lowers to a log-depth unrolled tree --
        # counted correctly by the cost model; no correction.
    if cfg.mtp:
        total = total + _attn_layer(cfg, B, S, q_chunk, kv_chunk)
    if include_encoder and cfg.is_encdec:
        for _ in range(cfg.encoder_layers):
            total = total + _attn_layer(cfg, enc_B, enc_S, q_chunk, kv_chunk)
    return total


def correction_for(cfg: ArchConfig, kind: str, *, B: int, S: int,
                   variant: str = "feddeper", tau: int = 1,
                   chips: int = 256) -> Correction:
    """Per-device correction for a full step record.

    ``B``: per-local-step batch rows (all clients); ``S``: sequence length.
    Train scales by the AD factor (fwd+bwd ~ 3x fwd matmul flops),
    FedDeper's two gradient streams, and tau local steps."""
    if kind == "train":
        fwd = forward_correction(
            cfg, B=B, S=S, include_encoder=True, enc_B=B,
            enc_S=cfg.frontend_tokens)
        grads = 2.0 if variant == "feddeper" else 1.0
        return fwd.scale(3.0 * grads * tau / chips)  # fwd+bwd
    if kind == "prefill":
        fwd = forward_correction(cfg, B=B, S=S, include_encoder=True,
                                 enc_B=B, enc_S=cfg.frontend_tokens)
        return fwd.scale(1.0 / chips)
    return Correction()  # decode: no rolled inner loops
