"""Serving driver on the repro.serve tier (DESIGN.md §13).

Batch mode (default): one cohort of uniform prompts, greedy decode in
jitted blocks, JSON summary.  ``--simulate`` runs the continuous
-batching request simulator instead: mixed prompt lengths, staggered
arrivals, slot reuse.

  # train, then serve the checkpoint:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \\
      --rounds 3 --ckpt-dir /tmp/run1
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \\
      --ckpt-dir /tmp/run1 --gen-tokens 32

  # int8-packed weights + request simulator:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \\
      --ckpt-dir /tmp/run1 --weights q8 --simulate --requests 8
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import ServeSpec, get_config
from repro.serve import ServeEngine, SimConfig, make_weight_source, simulate


def _build(args: ServeSpec):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    source = make_weight_source(args.resolve_weights())
    params = source.load(cfg)
    engine = ServeEngine(cfg, params, slots=args.slots,
                         max_len=args.max_len,
                         block_tokens=args.block_tokens)
    return cfg, source, engine


def _run_batch(cfg, source, engine, args: ServeSpec) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([args.seed, 0xBA7C]))
    prompts = [rng.integers(0, cfg.vocab_size, args.prompt_len,
                            dtype=np.int64).astype(np.int32)
               for _ in range(args.slots)]
    t0 = time.perf_counter()
    # warm every compile cache the timed run hits (prefill bucket,
    # admit, decode block); re-admission fully overwrites slot state
    engine.generate(prompts, min(args.gen_tokens,
                                 engine.block_tokens + 1))
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    gen = engine.generate(prompts, args.gen_tokens)
    dt = time.perf_counter() - t0
    return {
        "mode": "batch",
        "generated": int(gen.size),
        "tokens_per_s": round(gen.size / max(dt, 1e-9), 1),
        "compile_s": round(compile_s, 3),
        "decode_s": round(dt, 3),
        "sample_tokens": gen[0, :8].tolist(),
    }


def _run_simulate(cfg, source, engine, args: ServeSpec) -> dict:
    sim = SimConfig(requests=args.requests,
                    prompt_lens=args.parsed_prompt_lens(),
                    gen_tokens=args.gen_tokens, delay=args.delay,
                    delay_dist=args.delay_dist,
                    delay_sigma=args.delay_sigma, seed=args.seed,
                    time_unit=args.time_unit)
    m = simulate(engine, sim)
    m["mode"] = "simulate"
    return m


def main(argv=None):
    args = ServeSpec.from_args(argv).validate()
    cfg, source, engine = _build(args)
    out = _run_simulate(cfg, source, engine, args) if args.simulate \
        else _run_batch(cfg, source, engine, args)
    out.update({
        "arch": cfg.name,
        "weights": source.name,
        "resident_mb": round(source.resident_bytes(cfg) / 2 ** 20, 2),
        "slots": args.slots, "max_len": args.max_len,
        "block_tokens": args.block_tokens,
        "block_compiles": engine.block_compile_count(),
        "backend": jax.default_backend(),
    })
    print(json.dumps(out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
