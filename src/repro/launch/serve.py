"""Serving driver: batched prefill + greedy decode with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --batch 2 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import make_decode_step, make_prefill_step
from repro.models import init_cache, init_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(args.seed)
    params = init_model(cfg, rng)
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen

    batch = {"tokens": jax.random.randint(rng, (B, P), 0, cfg.vocab_size)}
    if cfg.frontend is not None:
        batch["frontend"] = 0.02 * jax.random.normal(
            rng, (B, cfg.frontend_tokens, cfg.d_model))
    cache = init_cache(cfg, B, max_len)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, logits, cache = decode(params, cache, tok, jnp.int32(P + i))
        out.append(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(json.dumps({
        "arch": cfg.name, "batch": B, "prompt_len": P, "generated": args.gen,
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round((args.gen - 1) * B / max(t_decode, 1e-9),
                                  1),
        "sample_tokens": gen[0, :8].tolist(),
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
