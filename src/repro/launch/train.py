"""Datacenter-regime training driver (runs the real round loop).

On the CPU container this runs reduced configs on a 1-device mesh with the
same code path the production mesh uses (client axis, tau scan, delta-mean
aggregation); on TPU hardware it runs unmodified with the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --clients 2 --tau 4 --rounds 20 --batch 2 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint, restore_checkpoint, \
    save_checkpoint
from repro.configs import get_config
from repro.core import FedDeper, STRATEGIES, make_round_step
from repro.data import lm_client_batch
from repro.models import init_model, transformer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant (CPU)")
    ap.add_argument("--strategy", default="feddeper",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2, help="per-client b")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eta", type=float, default=0.05)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    kw = dict(eta=args.eta)
    if args.strategy == "feddeper":
        kw.update(rho=args.rho, lam=args.lam)
    strategy = STRATEGIES[args.strategy](**kw)

    rng = jax.random.PRNGKey(args.seed)
    x = init_model(cfg, rng)
    C = args.clients
    client_state = jax.tree.map(
        lambda l: jnp.broadcast_to(l, (C,) + l.shape).copy(),
        strategy.client_init(x))
    server_state = strategy.server_init(x)
    step = jax.jit(make_round_step(cfg, strategy))

    start = 0
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            (x, client_state, server_state), meta = restore_checkpoint(
                path, (x, client_state, server_state))
            start = meta["step"]
            print(f"restored round {start} from {path}")

    def batch_for(round_k):
        per = [lm_client_batch(vocab=cfg.vocab_size, n_clients=C, client=c,
                               round_k=round_k, tau=args.tau,
                               batch=args.batch, seq_len=args.seq,
                               seed=args.seed)
               for c in range(C)]
        out = {}
        for key in per[0]:
            out[key] = jnp.asarray(np.stack([p[key] for p in per]))
        if cfg.frontend is not None:
            out["frontend"] = jnp.zeros(
                (C, args.tau, args.batch, cfg.frontend_tokens, cfg.d_model),
                jnp.float32)
        return out

    t0 = time.time()
    for k in range(start, args.rounds):
        x, server_state, client_state, metrics = step(
            x, server_state, client_state, batch_for(k))
        rec = {"round": k + 1,
               **{m: float(v) for m, v in metrics.items()},
               "elapsed_s": round(time.time() - t0, 2)}
        print(json.dumps(rec), flush=True)
        if args.ckpt_dir and (k + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, k + 1,
                            (x, client_state, server_state))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.rounds,
                        (x, client_state, server_state))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
