"""Training driver for the datacenter and buffered-async regimes.

On the CPU container this runs reduced configs on a 1-device mesh with the
same code path the production mesh uses (client axis, tau scan, delta-mean
aggregation); on TPU hardware it runs unmodified with the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --clients 2 --tau 4 --rounds 20 --batch 2 --seq 128

``--regime async`` swaps the synchronous round loop for the buffered
asynchronous regime (core/async_rounds.py): clients draw heterogeneous
delays, the server aggregates staleness-discounted buffers:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --regime async --clients 8 --concurrent 4 --buffer 2 \
      --delay 5 --rounds 20 --batch 2 --seq 64
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint, restore_checkpoint, \
    save_checkpoint
from repro.configs import get_config
from repro.core import (AsyncSimConfig, STRATEGIES, init_async_state,
                        make_async_round_fn, make_round_step)
from repro.core.federated import make_lm_grad_fn
from repro.data import lm_client_batch, make_federated_lm
from repro.models import init_model, transformer


def run_async(cfg, strategy, args):
    """Buffered-async LM training: heterogeneous client delays, versioned
    global model, staleness-discounted aggregation."""
    if cfg.frontend is not None:
        raise SystemExit("--regime async supports token-only archs")
    acfg = AsyncSimConfig(
        n_clients=args.clients, m_concurrent=args.concurrent,
        buffer_size=args.buffer, tau=args.tau, batch_size=args.batch,
        alpha=args.alpha, delay=args.delay, delay_dist=args.delay_dist,
        seed=args.seed)
    data = {k: jnp.asarray(v) for k, v in make_federated_lm(
        vocab=cfg.vocab_size, n_clients=args.clients,
        per_client=args.per_client, seq_len=args.seq,
        seed=args.seed).items()}
    grad_fn = make_lm_grad_fn(cfg)
    x = init_model(cfg, jax.random.PRNGKey(args.seed))
    state = init_async_state(acfg, strategy, x)
    round_fn = make_async_round_fn(acfg, strategy, grad_fn, data)

    # checkpoint the model pytrees + rng at aggregation boundaries;
    # in-flight slots/buffer are dropped, so a restart redispatches (the
    # staleness clock restarts too -- same semantics as clients rejoining)
    def ckpt_tree(s):
        return (s["x"], s["clients"], s["pms"], s["server"], s["rng"])

    start = 0
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            tree, meta = restore_checkpoint(path, ckpt_tree(state))
            (state["x"], state["clients"], state["pms"], state["server"],
             state["rng"]) = tree
            start = state["round"] = state["version"] = meta["step"]
            print(f"restored aggregation {start} from {path}")

    t0 = time.time()
    for k in range(start, args.rounds):
        state, metrics = round_fn(state)
        rec = {"round": k + 1,
               **{m: float(v) for m, v in metrics.items()},
               "elapsed_s": round(time.time() - t0, 2)}
        print(json.dumps(rec), flush=True)
        if args.ckpt_dir and (k + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, k + 1, ckpt_tree(state))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.rounds, ckpt_tree(state))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant (CPU)")
    ap.add_argument("--strategy", default="feddeper",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2, help="per-client b")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eta", type=float, default=0.05)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    # buffered-async regime (core/async_rounds.py)
    ap.add_argument("--regime", default="datacenter",
                    choices=("datacenter", "async"))
    ap.add_argument("--concurrent", type=int, default=4,
                    help="async: clients training simultaneously")
    ap.add_argument("--buffer", type=int, default=2,
                    help="async: uploads per aggregation")
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="async: staleness discount exponent")
    ap.add_argument("--delay", type=float, default=5.0,
                    help="async: mean client delay (0 = no stragglers)")
    ap.add_argument("--delay-dist", default="lognormal",
                    choices=("constant", "uniform", "lognormal"))
    ap.add_argument("--per-client", type=int, default=64,
                    help="async: LM sequences materialized per client")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    kw = dict(eta=args.eta)
    if args.strategy == "feddeper":
        kw.update(rho=args.rho, lam=args.lam)
    strategy = STRATEGIES[args.strategy](**kw)

    if args.regime == "async":
        return run_async(cfg, strategy, args)

    rng = jax.random.PRNGKey(args.seed)
    x = init_model(cfg, rng)
    C = args.clients
    client_state = jax.tree.map(
        lambda l: jnp.broadcast_to(l, (C,) + l.shape).copy(),
        strategy.client_init(x))
    server_state = strategy.server_init(x)
    step = jax.jit(make_round_step(cfg, strategy))

    start = 0
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            (x, client_state, server_state), meta = restore_checkpoint(
                path, (x, client_state, server_state))
            start = meta["step"]
            print(f"restored round {start} from {path}")

    def batch_for(round_k):
        per = [lm_client_batch(vocab=cfg.vocab_size, n_clients=C, client=c,
                               round_k=round_k, tau=args.tau,
                               batch=args.batch, seq_len=args.seq,
                               seed=args.seed)
               for c in range(C)]
        out = {}
        for key in per[0]:
            out[key] = jnp.asarray(np.stack([p[key] for p in per]))
        if cfg.frontend is not None:
            out["frontend"] = jnp.zeros(
                (C, args.tau, args.batch, cfg.frontend_tokens, cfg.d_model),
                jnp.float32)
        return out

    t0 = time.time()
    for k in range(start, args.rounds):
        x, server_state, client_state, metrics = step(
            x, server_state, client_state, batch_for(k))
        rec = {"round": k + 1,
               **{m: float(v) for m, v in metrics.items()},
               "elapsed_s": round(time.time() - t0, 2)}
        print(json.dumps(rec), flush=True)
        if args.ckpt_dir and (k + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, k + 1,
                            (x, client_state, server_state))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.rounds,
                        (x, client_state, server_state))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
