"""Training driver for the datacenter and buffered-async regimes.

On the CPU container this runs reduced configs on a 1-device mesh with the
same code path the production mesh uses (client axis, tau scan, delta-mean
aggregation); on TPU hardware it runs unmodified with the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --clients 2 --tau 4 --rounds 20 --batch 2 --seq 128

``--regime async`` swaps the synchronous round loop for the buffered
asynchronous regime (core/async_rounds.py): clients draw heterogeneous
delays, the server aggregates staleness-discounted buffers.  Adding
``--placement mesh`` distributes the dispatch cohorts over the client
axis (non-dividing sizes are padded with masked lanes) and lowers each
staleness-weighted aggregate to the round's single cross-client psum;
resumed runs (``--ckpt-dir``) restore the simulated clock and model
version from the checkpoint metadata, so sim_time never jumps backward:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --regime async --clients 8 --concurrent 4 --buffer 2 \
      --delay 5 --rounds 20 --batch 2 --seq 64

``--placement {vmap,mesh}`` routes the synchronous regime through the
cohort engine (core/engine.py) instead of the legacy fixed-cohort step:
client sampling + the placement-pluggable round executor on the
federated LM corpus.  ``mesh`` distributes the cohort and the client/pms
stores over the client axis of a mesh spanning every local device (on
CPU set ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` to
emulate K devices):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --placement mesh --clients 8 --sampled 4 --tau 4 \
      --rounds 10 --batch 2 --seq 64

``--block-rounds K`` (engine placements only) runs K rounds per jitted
``lax.scan`` block instead of one jitted call per round: one host sync
and one donation handoff per block, per-round metrics returned stacked,
held-out global eval + checkpoints at block boundaries.  Bitwise the
same trajectory as the per-round loop:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --placement vmap --clients 4 --tau 2 --rounds 12 \
      --block-rounds 4 --batch 2 --seq 64

``--compress {none,identity,q8,fp8,topk:R}`` (engine placements and the
async regime) compresses each client's uplink delta through the comm
layer (repro/comm): per-leaf-scale int8/fp8 quantization or top-k
sparsification with client-side error feedback; records report the
resulting ``uplink_bytes_per_round``.  With ``--regime async`` and
``--bandwidth B`` every delivery additionally pays payload_bytes/B of
simulated time, so compression shortens the straggler queue:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --placement vmap --clients 4 --tau 2 --rounds 12 \
      --block-rounds 4 --batch 2 --seq 64 --compress topk:0.25

``--faults drop:P,corrupt:P[,mode:M,...]`` and ``--clip-norm C``
(engine placements) inject deterministic per-client faults and screen
them server-side (repro/faults): dropped/non-finite uploads become
zero-weight lanes inside the round's single psum, and records report
per-round ``screened``/``dropped`` counts.  With ``--ckpt-dir`` the
driver is crash-safe: a non-finite global model at a round/block
boundary rolls back to the last good state and retries with a reseeded
schedule (``--max-retries`` bounds it).  ``--regime async`` instead
takes ``--faults deadline:T``: dispatches finishing after T simulated
time units never deliver.  Resumed runs re-validate the checkpoint's
``compress``/``faults``/``robust`` metadata against the CLI and fail
fast on mismatch:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --placement vmap --clients 4 --tau 2 --rounds 12 \
      --batch 2 --seq 64 --faults drop:0.2,corrupt:0.05 --clip-norm 10

``--robust {none,trimmed:F,median,krum:F,bucket:B}`` (engine
placements) swaps the aggregate's plain mean for a Byzantine-robust
reducer (repro/robust, DESIGN.md §12): screening weights feed the trim,
``robust=none`` traces the identical program, and the mesh lowering
stays within a declared collective budget (trimmed/krum: one all-gather
+ one psum; bucket: the round's single psum).  Pair with the stealth
fault modes to run the attack-defense matrix:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --placement mesh --clients 8 --sampled 4 --tau 2 \
      --rounds 24 --batch 2 --seq 64 --faults collude:0.2 \
      --robust trimmed:0.25

``--store virtual[:host|:recon|:shard[:DIR]]`` (engine placements and
the async regime) swaps the dense ``(n_clients, ...)`` client/pms/EF
stores for the virtual client store (core/store.py): only the sampled
cohort's rows live on device, gathered from / scattered back to a host,
reconstructible, or checkpoint-shard backing tier.  Device memory drops
from O(n_clients) to O(m_sampled) at a bitwise-identical trajectory;
checkpoints write the backing tier as sidecar shard files instead of
densifying, and resume re-validates the store layout against the CLI:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --placement vmap --clients 100000 --sampled 8 --tau 2 \
      --rounds 4 --batch 2 --seq 64 --store virtual:recon
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint, restore_checkpoint, \
    save_checkpoint
from repro.comm import make_compressor, uplink_bytes_per_round
from repro.configs import RunSpec, get_config, list_configs
from repro.core import (AsyncSimConfig, RollbackGuard,
                        SimConfig, init_async_state, init_sim_state,
                        make_async_round_fn, make_block_fn,
                        make_global_eval, make_layout, make_placement,
                        make_round_fn, make_round_step, run_blocks)
from repro.faults import make_faults
from repro.robust import make_robust
from repro.core.federated import make_lm_grad_fn
from repro.data import lm_client_batch, make_federated_lm
from repro.models import init_model, transformer


def _require_token_arch(cfg, arch: str, flag: str):
    """The federated-LM paths train on token streams only; name the archs
    that work instead of leaving the user to bisect the registry."""
    if cfg.frontend is not None:
        token = ", ".join(a for a in sorted(list_configs())
                          if get_config(a).frontend is None)
        raise SystemExit(
            f"{flag} supports token-only archs ({token}); "
            f"{arch} has a {cfg.frontend!r} frontend")


def _ckpt_tree(s):
    """The checkpointed slice of a round-regime state: model pytrees +
    rng, plus the error-feedback residual store when a stateful
    compressor is in play ({} otherwise) -- dropping ``ef`` on restore
    would silently discard the EF-SGD mass scheduled for re-send and
    diverge the resumed trajectory.  Regime bookkeeping (round/version
    counters, async slots/buffer) is restored separately or dropped --
    see each caller."""
    return (s["x"], s["clients"], s["pms"], s["server"], s["rng"],
            s.get("ef", {}))


def _restore_state(state, args, expect=None):
    """Load the latest checkpoint (if any) into ``state`` in place;
    returns ``(resume_round, meta)``.  Counter keys are the caller's job:
    the shared tree carries only what ``_ckpt_tree`` names, and any
    regime-specific counters (the async clock/version) travel in the
    checkpoint's metadata dict.

    ``expect`` ({key: canonical value}) re-validates the restored run's
    configuration against the CLI: a checkpoint written under a
    different ``compress``/``faults`` config fails fast instead of
    silently mixing EF/fault state into a mismatched trajectory.
    Legacy checkpoints without the keys restore unchecked."""
    if not args.ckpt_dir:
        return 0, {}
    path = latest_checkpoint(args.ckpt_dir)
    if not path:
        return 0, {}
    tree, meta = restore_checkpoint(path, _ckpt_tree(state))
    for key, want in (expect or {}).items():
        have = meta.get(key)
        if have is not None and str(have) != str(want):
            raise SystemExit(
                f"checkpoint {path} was written with {key}={have!r} but "
                f"this run requests {key}={want!r}: resuming would mix "
                "incompatible error-feedback/fault state -- rerun with "
                f"matching flags or a fresh --ckpt-dir")
    (state["x"], state["clients"], state["pms"], state["server"],
     state["rng"], ef) = tree
    if jax.tree.leaves(ef):
        state["ef"] = ef
    print(f"restored round {meta['step']} from {path}")
    return meta["step"], meta


def _drive_rounds(state, round_fn, args, start: int, rec_extra=None,
                  meta_fn=None, base_meta=None, guard=None):
    """The shared round loop: JSON line per round, periodic + final
    checkpoints.  One copy so every regime inherits identical restore/
    save/print semantics.  ``meta_fn(state) -> dict`` supplies extra
    checkpoint metadata (the async regime's simulated clock/version);
    ``base_meta`` is static metadata stamped into every save (the
    compress/faults config the resume path re-validates).

    ``guard`` (core.RollbackGuard) makes the loop crash-safe: a round
    that leaves the global model non-finite is DISCARDED -- the guard
    restores the last good state with a reseeded rng, a rollback record
    is printed, and the same round re-runs (bounded by the guard's retry
    counter)."""
    t0 = time.time()

    def _save(step):
        meta = dict(base_meta or {})
        if meta_fn:
            meta.update(meta_fn(state))
        save_checkpoint(args.ckpt_dir, step, _ckpt_tree(state),
                        metadata=meta or None)

    k = start
    while k < args.rounds:
        state, metrics = round_fn(state)
        if guard is not None:
            state, ok = guard.after(state)
            if not ok:
                print(json.dumps({"round": k + 1, "rollback": 1.0,
                                  "rollbacks": guard.rollbacks}),
                      flush=True)
                continue
        rec = {"round": k + 1, **(rec_extra or {}),
               **{m: float(v) for m, v in metrics.items()},
               "elapsed_s": round(time.time() - t0, 2)}
        if guard is not None:
            rec["rollbacks"] = guard.rollbacks
        print(json.dumps(rec), flush=True)
        k += 1
        if args.ckpt_dir and k % args.ckpt_every == 0:
            _save(k)
    if args.ckpt_dir:
        _save(args.rounds)
    return 0


def run_async(cfg, strategy, args):
    """Buffered-async LM training: heterogeneous client delays, versioned
    global model, staleness-discounted aggregation."""
    _require_token_arch(cfg, args.arch, "--regime async")
    compressor = make_compressor(args.compress)
    faults = make_faults(args.faults)
    layout = make_layout(args.store)
    placement = make_placement(args.placement) if args.placement else None
    acfg = AsyncSimConfig(
        n_clients=args.clients, m_concurrent=args.concurrent,
        buffer_size=args.buffer, tau=args.tau, batch_size=args.batch,
        alpha=args.alpha, delay=args.delay, delay_dist=args.delay_dist,
        delay_sigma=args.delay_sigma, seed=args.seed,
        bandwidth=args.bandwidth)
    data = {k: jnp.asarray(v) for k, v in make_federated_lm(
        vocab=cfg.vocab_size, n_clients=args.clients,
        per_client=args.per_client, seq_len=args.seq,
        seed=args.seed).items()}
    grad_fn = make_lm_grad_fn(cfg)
    x = init_model(cfg, jax.random.PRNGKey(args.seed))
    state = init_async_state(acfg, strategy, x, compressor=compressor,
                             placement=placement, layout=layout)
    round_fn = make_async_round_fn(acfg, strategy, grad_fn, data,
                                   compressor=compressor,
                                   placement=placement, faults=faults)

    # checkpoints land at aggregation boundaries; in-flight slots/buffer
    # are dropped, so a restart redispatches -- but the simulated clock
    # and model version persist in the checkpoint metadata: sim_time and
    # the staleness reference never jump backward across restarts.  The
    # canonical compress/faults specs are stamped into every save and
    # re-validated on restore (fail fast over silent config mixing).
    cfg_meta = args.to_meta()
    start, meta = _restore_state(state, args, expect=cfg_meta)
    state["round"] = start
    state["version"] = int(meta.get("version", start))
    state["t"] = float(meta.get("t", 0.0))
    return _drive_rounds(
        state, round_fn, args, start,
        rec_extra={"compress": args.compress,
                   "placement": args.placement or "vmap",
                   "uplink_bytes_per_round": uplink_bytes_per_round(
                       compressor, strategy, x, acfg.buffer_size)},
        meta_fn=lambda s: {"t": float(s["t"]),
                           "version": int(s["version"])},
        base_meta=cfg_meta)


def _make_lm_eval(cfg, args):
    """Global-model eval for the block driver: next-token loss/accuracy
    on a HELD-OUT federated LM split (same Zipf client skew, disjoint
    seed), flattened across clients and scanned by ``make_global_eval``."""
    held = make_federated_lm(
        vocab=cfg.vocab_size, n_clients=args.clients,
        per_client=args.per_client, seq_len=args.seq,
        seed=args.seed + 1)
    flat = {k: jnp.asarray(v.reshape((-1,) + v.shape[2:]))
            for k, v in held.items()}

    def apply_loss(p, b):
        return transformer.loss_fn(cfg, p, b)

    return make_global_eval(apply_loss, flat)


def run_engine(cfg, strategy, args):
    """Engine-based synchronous regime (``--placement``): client sampling
    + the placement-pluggable cohort executor (core/engine.py) on the
    federated LM corpus.  ``vmap`` keeps the cohort on one device;
    ``mesh`` distributes cohort + stores over the client axis of a mesh
    spanning every local device.

    ``--block-rounds K`` swaps the host round loop for the scan-compiled
    block driver (``engine.make_block_fn``): ceil(rounds/K) jitted blocks
    of K rounds each, ONE host sync + donation handoff per block, with
    held-out global eval (and checkpoints) at block boundaries.  The
    trajectory is bitwise the K=1 host loop's -- only the sync/eval
    cadence changes."""
    _require_token_arch(cfg, args.arch, "--placement")
    placement = make_placement(args.placement)
    compressor = make_compressor(args.compress)
    layout = make_layout(args.store)
    faults = make_faults(args.faults, clip_norm=args.clip_norm)
    robust = make_robust(args.robust)
    if faults is not None and not faults.active:
        raise SystemExit("--faults deadline:T is the async regime's "
                         "straggler model: pass --regime async (the "
                         "synchronous engine has no simulated clock)")
    m = args.sampled or args.clients
    sim = SimConfig(n_clients=args.clients, m_sampled=m, tau=args.tau,
                    batch_size=args.batch, seed=args.seed)
    data = {k: jnp.asarray(v) for k, v in make_federated_lm(
        vocab=cfg.vocab_size, n_clients=args.clients,
        per_client=args.per_client, seq_len=args.seq,
        seed=args.seed).items()}
    grad_fn = make_lm_grad_fn(cfg)
    x = init_model(cfg, jax.random.PRNGKey(args.seed))
    state = init_sim_state(sim, strategy, x, placement=placement,
                           compressor=compressor, layout=layout)
    comm_extra = {"compress": args.compress,
                  "uplink_bytes_per_round": uplink_bytes_per_round(
                      compressor, strategy, x, m)}
    if faults is not None:
        comm_extra["faults"] = faults.spec
    if robust is not None:
        comm_extra["robust"] = robust.spec
    if layout.virtual:
        comm_extra["store"] = layout.spec
    cfg_meta = args.to_meta()

    start, _ = _restore_state(state, args, expect=cfg_meta)
    if start:
        state["round"] = jnp.asarray(start, jnp.int32)
        # restored arrays are host-loaded: re-place on the mesh
        state = placement.place_state(state)

    # crash-safe recovery under injected faults: snapshot the (possibly
    # restored) starting state, roll back + reseed on divergence
    guard = RollbackGuard(state, max_retries=args.max_retries,
                          place_state=placement.place_state) \
        if faults is not None else None

    if args.block_rounds:
        t0 = time.time()
        eval_fn = _make_lm_eval(cfg, args)

        def log(rec):
            print(json.dumps({**rec, "placement": placement.name,
                              **comm_extra,
                              "elapsed_s": round(time.time() - t0, 2)}),
                  flush=True)

        # block boundaries rarely land exactly on a ckpt_every multiple:
        # save at the FIRST boundary at/after each multiple (the per-round
        # loop's cadence, quantized up to block granularity)
        ckpt_mark = [start // args.ckpt_every] if args.ckpt_dir else None

        def on_block(s, done):
            if not args.ckpt_dir:
                return
            mark = (start + done) // args.ckpt_every
            if mark > ckpt_mark[0]:
                ckpt_mark[0] = mark
                save_checkpoint(args.ckpt_dir, start + done, _ckpt_tree(s),
                                metadata=cfg_meta)

        state, _ = run_blocks(
            state, lambda size: make_block_fn(
                sim, strategy, grad_fn, data, block_size=size,
                placement=placement, compressor=compressor,
                faults=faults, layout=layout, robust=robust),
            args.rounds - start, args.block_rounds, eval_fn=eval_fn,
            log=log, on_block=on_block, first_round=start, guard=guard)
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.rounds, _ckpt_tree(state),
                            metadata=cfg_meta)
        return 0

    round_fn = make_round_fn(sim, strategy, grad_fn, data,
                             placement=placement, compressor=compressor,
                             faults=faults, layout=layout, robust=robust)
    return _drive_rounds(state, round_fn, args, start,
                         rec_extra={"placement": placement.name,
                                    **comm_extra},
                         base_meta=cfg_meta, guard=guard)


def main(argv=None):
    """CLI entry: the full flag surface is ``configs.run.RunSpec`` --
    one field per flag, ``--config run.json`` accepted alongside flags
    (explicit flags override the file), cross-flag guard rails in
    ``RunSpec.validate``."""
    args = RunSpec.from_args(argv).validate()

    cfg = args.arch_config()
    strategy = args.make_strategy()

    if args.regime == "async":
        return run_async(cfg, strategy, args)
    if args.placement:
        return run_engine(cfg, strategy, args)

    rng = jax.random.PRNGKey(args.seed)
    x = init_model(cfg, rng)
    C = args.clients
    client_state = jax.tree.map(
        lambda l: jnp.broadcast_to(l, (C,) + l.shape).copy(),
        strategy.client_init(x))
    server_state = strategy.server_init(x)
    step = jax.jit(make_round_step(cfg, strategy))

    start = 0
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            (x, client_state, server_state), meta = restore_checkpoint(
                path, (x, client_state, server_state))
            start = meta["step"]
            print(f"restored round {start} from {path}")

    def batch_for(round_k):
        per = [lm_client_batch(vocab=cfg.vocab_size, n_clients=C, client=c,
                               round_k=round_k, tau=args.tau,
                               batch=args.batch, seq_len=args.seq,
                               seed=args.seed)
               for c in range(C)]
        out = {}
        for key in per[0]:
            out[key] = jnp.asarray(np.stack([p[key] for p in per]))
        if cfg.frontend is not None:
            out["frontend"] = jnp.zeros(
                (C, args.tau, args.batch, cfg.frontend_tokens, cfg.d_model),
                jnp.float32)
        return out

    t0 = time.time()
    for k in range(start, args.rounds):
        x, server_state, client_state, metrics = step(
            x, server_state, client_state, batch_for(k))
        rec = {"round": k + 1,
               **{m: float(v) for m, v in metrics.items()},
               "elapsed_s": round(time.time() - t0, 2)}
        print(json.dumps(rec), flush=True)
        if args.ckpt_dir and (k + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, k + 1,
                            (x, client_state, server_state))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.rounds,
                        (x, client_state, server_state))
    return 0



if __name__ == "__main__":
    raise SystemExit(main())
