"""repro: FedDeper (AAAI-22) as a production multi-pod JAX framework."""
__version__ = "1.0.0"
