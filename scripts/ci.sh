#!/usr/bin/env bash
# Tier-1 CI gate: install dev deps where possible, then run the fast
# (non-slow) suite.  Collection errors and test regressions fail fast.
set -euo pipefail
cd "$(dirname "$0")/.."

# Offline containers ship without pip access; the suite degrades
# gracefully (hypothesis-based modules importorskip themselves).
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: dev deps not installable (offline?); continuing" >&2

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q -m "not slow" "$@"

# Multi-device shard: the mesh-placement + block-scan equivalence tests.
# The 4-device coverage runs in subprocesses that set
# XLA_FLAGS=--xla_force_host_platform_device_count=4 themselves (the
# parent process must NOT carry that flag -- tests/conftest.py asserts
# so).  The unfiltered main run above already executes these files, so
# the explicit shard only fires when extra args were passed and may have
# filtered them out.  (Option-only args like -q re-run the files
# redundantly -- harmless, and cheaper than parsing pytest's CLI here.)
if [ "$#" -gt 0 ]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m pytest -x -q tests/test_engine_placement.py \
        tests/test_block_scan.py tests/test_sharding_rules.py
fi

# Quick-mode round-engine bench smoke: run the headline fused-vs-unfused
# pairs end to end and fail on schema errors.  BENCH_round_engine.json is
# regenerated only when missing -- an existing tracked baseline (rounds=12,
# reps=3) is never clobbered with the smoke's 2-round samples; those go to
# a scratch file that is schema-validated alongside the checked-in one.
# A full baseline refresh is `python -m benchmarks.run --only round_engine`.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'PY'
import json
import tempfile
from pathlib import Path

from benchmarks.round_engine import (BENCH_PATH, round_engine_rows,
                                     validate_bench)

scratch = None if not BENCH_PATH.exists() else \
    Path(tempfile.NamedTemporaryFile(suffix=".json", delete=False).name)
try:
    rows = round_engine_rows(
        quick=True, rounds=2, reps=1, out_path=scratch or BENCH_PATH,
        include=("feddeper_sync_unfused", "feddeper_sync_fused",
                 "feddeper_sync_pallas_unfused",
                 "feddeper_sync_pallas_fused", "feddeper_sync_mesh",
                 "feddeper_sync_block4", "feddeper_sync_mesh_block4"))
    for r in rows:
        print(r)
    validate_bench(json.loads(BENCH_PATH.read_text()))
    if scratch is not None:
        validate_bench(json.loads(scratch.read_text()))
finally:
    if scratch is not None:
        scratch.unlink(missing_ok=True)
print(f"ci.sh: bench smoke OK ({BENCH_PATH} schema valid)")
PY
