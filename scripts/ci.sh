#!/usr/bin/env bash
# Tier-1 CI gate: install dev deps where possible, then run the fast
# (non-slow) suite.  Collection errors and test regressions fail fast.
set -euo pipefail
cd "$(dirname "$0")/.."

# Offline containers ship without pip access; the suite degrades
# gracefully (hypothesis-based modules importorskip themselves).
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: dev deps not installable (offline?); continuing" >&2

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q -m "not slow" "$@"
