#!/usr/bin/env bash
# Tier-1 CI gate.
#
#   scripts/ci.sh [--shard unit|multidev|bench|virtual|all] [pytest args...]
#
# Shards (each one a lane in .github/workflows/ci.yml):
#   unit     -- the fast (non-slow) suite;
#   multidev -- the mesh-placement / block-scan / sharding-rules /
#               compression equivalence files (their 4-device coverage
#               runs in subprocesses that set
#               XLA_FLAGS=--xla_force_host_platform_device_count=4
#               themselves; the parent must NOT carry that flag --
#               tests/conftest.py asserts so);
#   bench    -- quick-mode round-engine smoke: schema validation of the
#               tracked baseline AND the speedup regression gate
#               (benchmarks.round_engine.check_speedups);
#   virtual  -- the virtual client store lane: the full dense-vs-virtual
#               bitwise suite (tests/test_virtual_store.py, including
#               the bigmem n=100k cohort-footprint smoke) plus the n=1k
#               virtual bench row, schema-validated and gated on
#               peak_bytes against the tracked baseline (MEM_TOL);
#   serve    -- the serving tier lane: flash-decode / engine / config-
#               API tests plus a BENCH_serve smoke, schema-validated
#               and gated (speedup_vs_loop + peak_bytes) against the
#               tracked BENCH_serve.json;
#   all      -- everything above (the no-argument default).
set -euo pipefail
cd "$(dirname "$0")/.."

SHARD=all
if [ "${1:-}" = "--shard" ]; then
    SHARD="${2:?--shard needs unit|multidev|bench|virtual|all}"
    shift 2
fi

# Offline containers ship without pip access; the suite degrades
# gracefully (hypothesis-based modules importorskip themselves).
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: dev deps not installable (offline?); continuing" >&2

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MULTIDEV_FILES=(tests/test_engine_placement.py tests/test_block_scan.py
                tests/test_sharding_rules.py tests/test_compression.py
                tests/test_async_mesh.py tests/test_faults.py
                tests/test_robust.py)

run_unit() {
    python -m pytest -x -q -m "not slow" "$@"
}

run_multidev() {
    python -m pytest -x -q "${MULTIDEV_FILES[@]}"
}

run_bench() {
    # Quick-mode round-engine bench smoke: run the headline pairs end to
    # end, fail on schema errors AND on tracked-speedup regressions.
    # BENCH_round_engine.json is regenerated only when missing -- an
    # existing tracked baseline (rounds=12, reps=3+) is never clobbered
    # with the smoke's 2-round samples; those go to a scratch file that
    # is schema-validated and ratio-gated against the checked-in one.
    # A full baseline refresh is `python -m benchmarks.run --only
    # round_engine`.
    python - <<'PY'
import json
import sys
import tempfile
from pathlib import Path

from benchmarks.round_engine import (BENCH_PATH, check_speedups,
                                     round_engine_rows, validate_bench)

scratch = None if not BENCH_PATH.exists() else \
    Path(tempfile.NamedTemporaryFile(suffix=".json", delete=False).name)
try:
    rows = round_engine_rows(
        quick=True, rounds=2, reps=1, out_path=scratch or BENCH_PATH,
        include=("feddeper_sync_unfused", "feddeper_sync_fused",
                 "feddeper_sync_pallas_unfused",
                 "feddeper_sync_pallas_fused", "feddeper_sync_mesh",
                 "feddeper_sync_block4", "feddeper_sync_mesh_block4",
                 "feddeper_sync_identity", "feddeper_sync_q8",
                 "feddeper_sync_topk", "feddeper_sync_faults",
                 "feddeper_sync_robust",
                 "feddeper_async_fused", "feddeper_async_unfused",
                 "feddeper_async_mesh"))
    for r in rows:
        print(r)
    tracked = json.loads(BENCH_PATH.read_text())
    validate_bench(tracked)
    if scratch is not None:
        smoke = json.loads(scratch.read_text())
        validate_bench(smoke)
        fails = check_speedups(smoke, tracked)
        if fails:
            print("ci.sh: bench regression gate FAILED:", file=sys.stderr)
            for f in fails:
                print(f"  {f}", file=sys.stderr)
            sys.exit(1)
        print("ci.sh: bench regression gate OK "
              f"({len(smoke)} smoke rows vs tracked baseline)")
finally:
    if scratch is not None:
        scratch.unlink(missing_ok=True)
print(f"ci.sh: bench smoke OK ({BENCH_PATH} schema valid)")
PY
}

run_virtual() {
    # Dense-vs-virtual equivalence suite, including the deselected-by-
    # default bigmem n=100k smoke (cheap in wall time -- the recon tier
    # materializes only touched rows -- but population-scale in intent).
    python -m pytest -x -q -m "" tests/test_virtual_store.py
    # n=1k virtual bench row: schema (store_bytes required) + the
    # peak_bytes memory gate against the tracked baseline.
    python - <<'PY'
import json
import sys
import tempfile
from pathlib import Path

from benchmarks.round_engine import (BENCH_PATH, check_speedups,
                                     round_engine_rows, validate_bench)

scratch = None if not BENCH_PATH.exists() else \
    Path(tempfile.NamedTemporaryFile(suffix=".json", delete=False).name)
try:
    rows = round_engine_rows(
        quick=True, rounds=2, reps=1, out_path=scratch or BENCH_PATH,
        include=("feddeper_sync_virtual_n1k",))
    for r in rows:
        print(r)
    tracked = json.loads(BENCH_PATH.read_text())
    validate_bench(tracked)
    if scratch is not None:
        smoke = json.loads(scratch.read_text())
        validate_bench(smoke)
        fails = check_speedups(smoke, tracked)
        if fails:
            print("ci.sh: virtual bench gate FAILED:", file=sys.stderr)
            for f in fails:
                print(f"  {f}", file=sys.stderr)
            sys.exit(1)
        print("ci.sh: virtual bench memory gate OK")
finally:
    if scratch is not None:
        scratch.unlink(missing_ok=True)
PY
}

run_serve() {
    # Serving-tier lane: the kernel/engine/config test files, then a
    # quick BENCH_serve smoke.  Same scratch-file discipline as
    # run_bench: an existing tracked baseline is never clobbered by the
    # reps=1 smoke; it is schema-validated and gated against the
    # checked-in one (check_speedups is generic over speedup_vs_* and
    # peak_bytes).  A full baseline refresh is `python -m
    # benchmarks.run --only serve`.
    python -m pytest -x -q tests/test_serve.py tests/test_runspec.py
    python - <<'PY'
import json
import sys
import tempfile
from pathlib import Path

from benchmarks.round_engine import check_speedups
from benchmarks.serve_bench import (BENCH_PATH, serve_rows,
                                    validate_serve_bench)

scratch = None if not BENCH_PATH.exists() else \
    Path(tempfile.NamedTemporaryFile(suffix=".json", delete=False).name)
try:
    rows = serve_rows(quick=True, reps=1,
                      out_path=scratch or BENCH_PATH,
                      include=("block", "simulate"))
    for r in rows:
        print(r)
    tracked = json.loads(BENCH_PATH.read_text())
    validate_serve_bench(tracked)
    if scratch is not None:
        smoke = json.loads(scratch.read_text())
        validate_serve_bench(smoke)
        fails = check_speedups(smoke, tracked)
        if fails:
            print("ci.sh: serve bench gate FAILED:", file=sys.stderr)
            for f in fails:
                print(f"  {f}", file=sys.stderr)
            sys.exit(1)
        print("ci.sh: serve bench gate OK "
              f"({len(smoke)} smoke rows vs tracked baseline)")
finally:
    if scratch is not None:
        scratch.unlink(missing_ok=True)
print(f"ci.sh: serve bench smoke OK ({BENCH_PATH} schema valid)")
PY
}

case "$SHARD" in
unit)     run_unit "$@" ;;
multidev) run_multidev ;;
bench)    run_bench ;;
virtual)  run_virtual ;;
serve)    run_serve ;;
all)
    run_unit "$@"
    # The unfiltered run above already executes the multidev files, so
    # the explicit shard only fires when a *positional* pytest arg (a
    # file/dir/node id, or an option value like -k's pattern) may have
    # filtered them out.  Option-only invocations (-q, -x, ...) used to
    # re-run the files redundantly; now they don't.
    has_filter=0
    for a in "$@"; do
        case "$a" in
        -*) ;;
        *) has_filter=1 ;;
        esac
    done
    if [ "$has_filter" = 1 ]; then
        run_multidev
    fi
    run_bench
    run_virtual
    run_serve
    ;;
*)
    echo "ci.sh: unknown shard '$SHARD' (want unit|multidev|bench|" \
         "virtual|serve|all)" >&2
    exit 2
    ;;
esac
