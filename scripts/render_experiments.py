"""Render §Dry-run and §Roofline markdown tables in EXPERIMENTS.md from
experiments/dryrun.jsonl (between AUTOGEN markers).

    PYTHONPATH=src python scripts/render_experiments.py
"""
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import load_records  # noqa: E402


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | kind | status | compile | "
            "args/dev | temp/dev | HLO GFLOPs/dev | coll MB/dev | "
            "collectives |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("variant", "feddeper") != "feddeper":
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                        f"skipped (documented) | - | - | - | - | - | - |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                        f"ERROR | - | - | - | - | - | - |")
            continue
        mem = r.get("memory", {})
        counts = ", ".join(f"{k}:{v}" for k, v in sorted(
            r.get("collective_counts", {}).items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | ok "
            f"| {r['compile_s']:.0f}s "
            f"| {fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(mem.get('temp_size_in_bytes'))} "
            f"| {r['flops_per_device'] / 1e9:,.0f} "
            f"| {r['collective_bytes_per_device'] / 1e6:,.1f} "
            f"| {counts} |")
    return "\n".join(rows)


def _advice(r) -> str:
    """One sentence: what moves the dominant term down (per the spec)."""
    dom, kind, arch = r["dominant"], r["kind"], r["arch"]
    moe = arch in ("deepseek-v3-671b", "granite-moe-3b-a800m",
                   "jamba-v0.1-52b")
    if dom == "compute":
        if moe:
            return ("sort-based dispatch + shard_map expert all-to-all "
                    "(implemented, see §Perf P3) removes the redundant "
                    "dispatch math")
        return ("causal block skipping in attention (Pallas kernel's "
                "pl.when) halves prefill FLOPs")
    if dom == "memory":
        if kind == "train":
            return ("remat (--remat) trades activation traffic for "
                    "recompute; bytes term here is XLA's no-fusion bound "
                    "-- analytic floor is the target")
        if kind == "decode":
            return ("int8/fp8 KV-cache quantization halves cache reads; "
                    "larger decode batch amortizes the weight pass")
        return "fuse attention (flash kernel) to kill score-matrix traffic"
    if kind == "train":
        return ("FedDeper's own lever: raise tau (sync bytes / tau) or "
                "fp8 delta uploads (--upload-dtype)")
    if kind == "decode":
        return ("seq-parallel flash-decode with owner-local cache update "
                "(--seq-decode, §Perf P5) removes per-layer cache "
                "resharding")
    return ("overlap tensor-parallel all-gathers with matmuls; "
            "reduce-scatter the FFN activations instead of all-reducing")


def roofline_table(recs):
    rows = ["| arch | shape | compute | memory (HLO) | memory "
            "(analytic) | collective | dominant | MODEL_FLOPS | "
            "useful/HLO | what moves the dominant term |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok" or r.get("variant", "feddeper") != "feddeper":
            continue
        if r["mesh"] != "16x16":
            continue  # roofline table is single-pod per the spec
        rolled = not r.get("unroll_layers", True)
        if rolled:
            # rolled layer scan: HLO terms count one layer of the stack --
            # report the analytic compute/memory estimates instead and
            # mark the row (compile-proof + memory-analysis remain exact)
            compute = f"~{fmt_s(r['model_flops'] / r['chips'] / 197e12)}"
            mem_hlo = "n/a†"
            useful = "n/a†"
            dom = "n/a†"
        else:
            compute = fmt_s(r["compute_s"])
            mem_hlo = fmt_s(r["memory_s"])
            useful = f"{r['useful_flops_ratio']:.2f}"
            dom = f"**{r['dominant']}**"
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {compute} | {mem_hlo} "
            f"| {fmt_s(max(0, r.get('analytic_memory_s', 0)))} "
            f"| {fmt_s(r['collective_s'])} | {dom} "
            f"| {r['model_flops'] / 1e12:,.0f}T "
            f"| {useful} | {_advice(r)} |")
    return "\n".join(rows)


def splice(text, marker, table):
    begin, end = f"<!-- AUTOGEN:{marker} -->", f"<!-- /AUTOGEN:{marker} -->"
    pattern = re.compile(re.escape(begin) + ".*?" + re.escape(end),
                         re.DOTALL)
    return pattern.sub(begin + "\n" + table + "\n" + end, text)


def perf_table(path):
    import json as _json
    if not os.path.exists(path):
        return "(no perf records yet)"
    rows = ["| tag | arch | shape | mesh | variant | compute | memory | "
            "collective | dominant | useful/HLO |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    with open(path) as f:
        for line in f:
            try:
                r = _json.loads(line)
            except _json.JSONDecodeError:
                continue
            if r.get("status") != "ok":
                rows.append(f"| {r.get('tag','')} | {r.get('arch')} | "
                            f"{r.get('shape')} | {r.get('mesh')} | - | - | "
                            f"- | - | ERROR | - |")
                continue
            rows.append(
                f"| {r.get('tag') or '(default)'} | {r['arch']} "
                f"| {r['shape']} | {r['mesh']} | {r.get('variant')} "
                f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {r['dominant']} "
                f"| {r['useful_flops_ratio']:.2f} |")
    return "\n".join(rows)


def main():
    recs = sorted(load_records(),
                  key=lambda r: (r.get("arch", ""), r.get("shape", ""),
                                 r.get("mesh", "")))
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    text = open(path).read()
    text = splice(text, "dryrun", dryrun_table(recs))
    text = splice(text, "roofline", roofline_table(recs))
    perf_path = os.path.join(os.path.dirname(__file__), "..",
                             "experiments", "perf.jsonl")
    text = splice(text, "perf", perf_table(perf_path))
    open(path, "w").write(text)
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skipped")
    print(f"rendered {ok} ok + {sk} skipped records")


if __name__ == "__main__":
    main()
