"""Recompute derived fields of dry-run records (params, model_flops,
useful_flops_ratio, analytic bytes) after the int32 param_count fix.
Measured fields (HLO flops/bytes/collectives, memory analysis) are raw
compiler outputs and remain untouched.  Usage:

    PYTHONPATH=src python scripts/fix_records.py experiments/dryrun.jsonl
"""
import json
import sys

from repro.configs import get_config
from repro.launch import hlo_analysis
from repro.launch.dryrun import analytic_bytes_for, model_flops_for
from repro.models import transformer


def fix(path):
    out_lines = []
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if rec.get("status") != "ok":
                out_lines.append(json.dumps(rec))
                continue
            cfg = get_config(rec["arch"])
            rec["params"] = transformer.param_count(cfg)
            rec["active_params"] = transformer.active_param_count(cfg)
            mflops = model_flops_for(cfg, rec["kind"], rec["meta"],
                                     rec.get("variant", "feddeper"))
            rec["model_flops"] = mflops
            flops = rec["flops_per_device"]
            rec["useful_flops_ratio"] = (mflops / (flops * rec["chips"])
                                         if flops else 0.0)
            abytes = analytic_bytes_for(cfg, rec["kind"], rec["meta"],
                                        rec.get("variant", "feddeper"),
                                        rec.get("tau", 4), rec["chips"],
                                        rec["shape"])
            rec["analytic_bytes_per_device"] = abytes
            rec["analytic_memory_s"] = abytes / hlo_analysis.HBM_BW
            out_lines.append(json.dumps(rec))
    with open(path, "w") as f:
        f.write("\n".join(out_lines) + "\n")
    print(f"fixed {len(out_lines)} records")


if __name__ == "__main__":
    fix(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.jsonl")
