"""FedDeper-vs-sync collective headline: cross-client bytes per optimizer
step, from the dry-run records.

    PYTHONPATH=src python scripts/collective_headline.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def load(path):
    recs = []
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return recs


def main():
    recs = load("experiments/dryrun.jsonl") + load("experiments/perf.jsonl")
    by = {}
    for r in recs:
        if r.get("status") != "ok" or r.get("shape") != "train_4k":
            continue
        key = (r["arch"], r["mesh"], r.get("variant"), r.get("tag", ""))
        by[key] = r
    out = []
    for (arch, mesh, variant, tag), r in sorted(by.items()):
        if variant != "sync":
            continue
        fd = by.get((arch, mesh, "feddeper", "")) or \
            by.get((arch, mesh, "feddeper", "fp8-upload"))
        if not fd:
            continue
        tau = fd.get("tau", 4)
        # normalize per TOKEN: sync consumes the full global batch in one
        # step; a feddeper round consumes it across tau local steps
        sync_tokens = r["meta"].get("tokens_per_step", 1)
        fd_tokens = fd["meta"].get("tokens_per_round", 1)
        sync_bpt = r["collective_bytes_per_device"] / sync_tokens
        fd_bpt = fd["collective_bytes_per_device"] / fd_tokens
        out.append({
            "arch": arch, "mesh": mesh, "tau": tau,
            "sync_coll_KB_per_token": round(sync_bpt / 1e3, 2),
            "feddeper_coll_KB_per_token": round(fd_bpt / 1e3, 2),
            "collective_reduction_x": round(sync_bpt / max(fd_bpt, 1e-9), 2),
            "compute_overhead_x": round(
                (fd["flops_per_device"] / fd_tokens)
                / max(r["flops_per_device"] / sync_tokens, 1e-9), 2),
        })
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
