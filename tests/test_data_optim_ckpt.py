"""Substrate tests: synthetic data pipeline, optimizers, checkpointing.

Hypothesis-free on purpose -- the property-based variants live in
test_property.py behind its module-level ``pytest.importorskip``, so this
module keeps collecting (and running) where ``hypothesis`` is absent.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.data import (heterogeneity_stats, lm_client_batch,
                        make_federated_classification)
from repro.optim import adamw, cosine_schedule, linear_warmup, sgd


# ---------------------------------------------------------------------- data

def test_dataset_deterministic():
    a = make_federated_classification(n_clients=4, per_client=64, seed=7)
    b = make_federated_classification(n_clients=4, per_client=64, seed=7)
    np.testing.assert_array_equal(a.train["x"], b.train["x"])
    np.testing.assert_array_equal(a.train["y"], b.train["y"])


def test_shards_split_is_heterogeneous():
    ds = make_federated_classification(n_clients=10, per_client=200,
                                       split="shards", seed=0)
    stats = heterogeneity_stats(ds)
    assert stats["mean_tv"] > 0.5  # pathological split: strong skew
    # each client sees few distinct labels
    for i in range(10):
        assert len(np.unique(ds.train["y"][i])) <= 4


def test_dirichlet_more_skew_than_high_alpha():
    lo = heterogeneity_stats(make_federated_classification(
        n_clients=8, per_client=256, split="dirichlet", alpha=0.1, seed=3))
    hi = heterogeneity_stats(make_federated_classification(
        n_clients=8, per_client=256, split="dirichlet", alpha=50.0, seed=3))
    assert lo["mean_tv"] > hi["mean_tv"]


def test_lm_client_batch_deterministic_and_skewed():
    a = lm_client_batch(vocab=128, n_clients=4, client=1, round_k=3, tau=2,
                        batch=2, seq_len=16, seed=5)
    b = lm_client_batch(vocab=128, n_clients=4, client=1, round_k=3, tau=2,
                        batch=2, seq_len=16, seed=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][..., 1:], a["labels"][..., :-1])
    # different clients see different unigram heads
    c = lm_client_batch(vocab=128, n_clients=4, client=2, round_k=3, tau=2,
                        batch=2, seq_len=16, seed=5)
    ha = np.bincount(a["tokens"].reshape(-1), minlength=128)
    hc = np.bincount(c["tokens"].reshape(-1), minlength=128)
    assert np.argmax(ha) != np.argmax(hc) or \
        0.5 * np.abs(ha / ha.sum() - hc / hc.sum()).sum() > 0.1


# --------------------------------------------------------------------- optim

def _quadratic_converges(opt, lr, steps=200):
    target = jnp.asarray([1.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for i in range(steps):
        g = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(g, state, params, lr)
    return float(jnp.max(jnp.abs(params["w"] - target)))


@pytest.mark.parametrize("opt,lr", [
    (sgd(), 0.1), (sgd(momentum=0.9), 0.05),
    (sgd(momentum=0.9, nesterov=True), 0.05),
    (adamw(weight_decay=0.0), 0.05),
])
def test_optimizers_converge(opt, lr):
    assert _quadratic_converges(opt, lr) < 1e-2


def test_adamw_weight_decay_shrinks():
    opt = adamw(weight_decay=0.5)
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    params, _ = opt.update({"w": jnp.zeros(4)}, state, params, 0.1)
    assert float(params["w"][0]) < 1.0


def test_schedules():
    warm = linear_warmup(1.0, 10)
    assert float(warm(jnp.int32(0))) == 0.0
    assert float(warm(jnp.int32(10))) == 1.0
    cos = cosine_schedule(1.0, 100, warmup_steps=10, min_frac=0.1)
    vals = [float(cos(jnp.int32(t))) for t in (0, 10, 55, 100)]
    assert vals[0] == 0.0 and abs(vals[1] - 1.0) < 1e-6
    assert vals[1] > vals[2] > vals[3] >= 0.1 - 1e-6


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "tup": (jnp.zeros(2), jnp.asarray(3))}
    d = str(tmp_path / "ckpt")
    p = save_checkpoint(d, 7, tree, metadata={"note": "x"})
    assert latest_checkpoint(d) == p
    restored, meta = restore_checkpoint(p, tree)
    assert meta["step"] == 7 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.zeros((2, 3))}
    p = save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(ValueError):
        restore_checkpoint(p, {"a": jnp.zeros((3, 2))})


def test_latest_checkpoint_ordering(tmp_path):
    d = str(tmp_path)
    for step in (3, 12, 7):
        save_checkpoint(d, step, {"a": jnp.zeros(1)})
    assert latest_checkpoint(d).endswith("ckpt_00000012.npz")


def test_checkpoint_midwrite_kill_is_atomic(tmp_path, monkeypatch):
    """A kill at ANY point during save never leaves a loadable-but-
    truncated checkpoint: the archive is written to a tmp name and
    renamed over the target only once complete.  Simulated by making
    np.savez write half the payload then die -- the target must be
    either absent or the intact PREVIOUS checkpoint, and no stale tmp
    may survive to trip a later save."""
    d = str(tmp_path)
    tree = {"a": jnp.arange(8, dtype=jnp.float32)}
    prev = save_checkpoint(d, 1, tree)

    real_savez = np.savez

    def dying_savez(f, **kw):
        some = {k: kw[k] for k in list(kw)[:1]}
        real_savez(f, **some)       # partial bytes hit the tmp file
        raise KeyboardInterrupt("simulated kill mid-write")

    monkeypatch.setattr(np, "savez", dying_savez)
    new_tree = {"a": jnp.full((8,), 9.0)}
    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(d, 2, new_tree)
    monkeypatch.setattr(np, "savez", real_savez)

    # target of the killed save never materialized; previous ckpt intact
    assert not os.path.exists(os.path.join(d, "ckpt_00000002.npz"))
    assert latest_checkpoint(d) == prev
    restored, meta = restore_checkpoint(prev, tree)
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    # no stale tmp left behind; the retried save lands cleanly
    assert not [f for f in os.listdir(d) if ".tmp" in f]
    p2 = save_checkpoint(d, 2, new_tree)
    assert latest_checkpoint(d) == p2
    restored2, _ = restore_checkpoint(p2, new_tree)
    np.testing.assert_array_equal(np.asarray(restored2["a"]), 9.0)
