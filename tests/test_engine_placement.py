"""Cohort-engine placement equivalence: the mesh placement must reproduce
the vmap placement (bitwise on a 1-device mesh; documented f32 tolerance
on a 4-device client axis, where the delta-mean associates as
mean-of-local-means), keep the client/pms stores distributed, and emit
exactly ONE cross-client collective per round (DESIGN.md §6)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.configs.paper_models import MLP_MNIST
from repro.core import (FedAvg, FedDeper, Scaffold, SimConfig,
                        MeshPlacement, init_sim_state, make_round_fn,
                        run_rounds)
from repro.data import make_federated_classification
from repro.launch.mesh import make_client_mesh
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def data():
    ds = make_federated_classification(n_clients=6, per_client=64,
                                       split="shards", seed=2)
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(11))


SIM = SimConfig(n_clients=6, m_sampled=4, tau=3, batch_size=16, seed=5)

COLLECTIVES = {"psum", "psum2", "all_gather", "all_to_all", "ppermute",
               "pmax", "pmin"}


def count_collectives(jaxpr) -> int:
    """Recursively count collective primitives in a (closed) jaxpr."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVES:
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "eqns"):
                n += count_collectives(v)
            elif hasattr(v, "jaxpr"):
                n += count_collectives(v.jaxpr)
    return n


def _run(strategy, data, x0, placement=None, rounds=3):
    state = init_sim_state(SIM, strategy, x0, placement=placement)
    rf = make_round_fn(SIM, strategy, grad_fn, data, placement=placement)
    return run_rounds(state, rf, rounds)


@pytest.mark.parametrize("strategy", [
    FedDeper(eta=0.05, rho=0.03, lam=0.5),
    FedAvg(eta=0.05),
], ids=["feddeper", "fedavg"])
def test_mesh_placement_bitwise_on_1device_mesh(strategy, data, x0):
    """On a 1-device mesh the shard_map round is the vmap round bitwise:
    the psum over a size-1 axis is an identity and the mean-of-local-
    means divides by 1.0 exactly (XLA:CPU)."""
    ref, hist_v = _run(strategy, data, x0)
    mesh, hist_m = _run(strategy, data, x0,
                        placement=MeshPlacement(make_client_mesh()))
    for key in ("x", "clients", "pms"):
        for a, b in zip(jax.tree.leaves(ref[key]),
                        jax.tree.leaves(mesh[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)
    for hv, hm in zip(hist_v, hist_m):
        assert set(hv) == set(hm)
        for k in hv:
            np.testing.assert_allclose(hv[k], hm[k], rtol=0, atol=0)


@pytest.mark.parametrize("strategy", [
    FedDeper(eta=0.05, rho=0.03, lam=0.5),
    Scaffold(eta=0.05),
], ids=["feddeper", "scaffold"])
def test_mesh_round_has_exactly_one_collective(strategy, data, x0):
    """tau local steps carry zero cross-client traffic; the delta-mean
    (and, bundled into the same psum, the metric scalars -- Scaffold's
    dv AND dc too) is the round's single collective."""
    pl = MeshPlacement(make_client_mesh())
    rf = make_round_fn(SIM, strategy, grad_fn, data, placement=pl,
                       donate=False)
    state = init_sim_state(SIM, strategy, x0, placement=pl)
    jaxpr = jax.make_jaxpr(rf)(state)
    assert count_collectives(jaxpr.jaxpr) == 1


def test_vmap_round_has_no_collectives(data, x0):
    rf = make_round_fn(SIM, FedDeper(eta=0.05), grad_fn, data,
                       donate=False)
    state = init_sim_state(SIM, FedDeper(eta=0.05), x0)
    assert count_collectives(jax.make_jaxpr(rf)(state).jaxpr) == 0


def test_mesh_placement_donation_keeps_round_alive(data, x0):
    """The donating mesh round keeps working across rounds (donated
    sharded buffers are reused, the returned state stays valid)."""
    pl = MeshPlacement(make_client_mesh())
    state, hist = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5), data, x0,
                       placement=pl, rounds=2)
    assert np.isfinite(hist[-1]["local_loss"])
    assert int(state["round"]) == 2


# ------------------------------------------------- 4-device CPU emulation

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.paper_models import MLP_MNIST
    from repro.core import (FedAvg, FedDeper, SimConfig, MeshPlacement,
                            init_sim_state, make_round_fn, run_rounds)
    from repro.data import make_federated_classification
    from repro.launch.mesh import make_client_mesh
    from repro.models import classifier_loss, init_classifier
    from repro.sharding import rules

    assert jax.local_device_count() == 4

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(
            lambda p, b: classifier_loss(MLP_MNIST, p, b),
            has_aux=True)(p, mb)
        return l, g

    ds = make_federated_classification(n_clients=8, per_client=64,
                                       split="shards", seed=2)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    x0 = init_classifier(MLP_MNIST, jax.random.PRNGKey(11))
    sim = SimConfig(n_clients=8, m_sampled=4, tau=2, batch_size=16,
                    seed=5)
    mesh = make_client_mesh()
    pl = MeshPlacement(mesh)

    # m must divide the 4-way client axis
    try:
        pl.check(SimConfig(8, 3, 2, 16))
        raise AssertionError("expected ValueError for m=3 on 4 shards")
    except ValueError:
        pass

    # ... but cohort_map (the async dispatch path) PADS non-dividing
    # cohorts with masked edge lanes and slices the outputs back, so a
    # cohort of 3 runs on the 4-way axis (it used to fail fast here)
    out3 = pl.cohort_map(lambda a: a + 1.0, in_axes=(0,))(
        jnp.arange(6.0).reshape(3, 2))
    np.testing.assert_array_equal(np.asarray(out3),
                                  np.arange(6.0).reshape(3, 2) + 1.0)

    for strat in (FedDeper(eta=0.05, rho=0.03, lam=0.5),
                  FedAvg(eta=0.05)):
        sv, _ = run_rounds(init_sim_state(sim, strat, x0),
                           make_round_fn(sim, strat, grad_fn, data), 3)
        sm, _ = run_rounds(
            init_sim_state(sim, strat, x0, placement=pl),
            make_round_fn(sim, strat, grad_fn, data, placement=pl), 3)
        for key in ("x", "clients", "pms"):
            for a, b in zip(jax.tree.leaves(sv[key]),
                            jax.tree.leaves(sm[key])):
                # mean-of-local-means reorders f32 sums (DESIGN.md tol)
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=0, atol=1e-6,
                                           err_msg=f"{strat.name}/{key}")

    # stores really distributed over the client axis, kept across a
    # donating round
    strat = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    state = init_sim_state(sim, strat, x0, placement=pl)
    rf = make_round_fn(sim, strat, grad_fn, data, placement=pl)
    state, _ = rf(state)
    for store in ("clients", "pms"):
        for leaf in jax.tree.leaves(state[store]):
            assert leaf.sharding.spec[0] == "data", (store,
                                                    leaf.sharding.spec)
            assert len(leaf.sharding.device_set) == 4

    # exactly one cross-client collective in the whole round program
    def count(jx, names):
        n = 0
        for eqn in jx.eqns:
            if eqn.primitive.name in names:
                n += 1
            for v in eqn.params.values():
                if hasattr(v, "eqns"):
                    n += count(v, names)
                elif hasattr(v, "jaxpr"):
                    n += count(v.jaxpr, names)
        return n
    rf_nd = make_round_fn(sim, strat, grad_fn, data, placement=pl,
                          donate=False)
    state2 = init_sim_state(sim, strat, x0, placement=pl)
    names = {"psum", "psum2", "all_gather", "all_to_all", "ppermute"}
    assert count(jax.make_jaxpr(rf_nd)(state2).jaxpr, names) == 1

    # divisibility fallback: n=6 does not divide 4 -> stores come back
    # REPLICATED on the client dim (no error), cohort still mesh-mapped
    sim6 = SimConfig(n_clients=6, m_sampled=4, tau=2, batch_size=16,
                     seed=5)
    ds6 = make_federated_classification(n_clients=6, per_client=64,
                                        split="shards", seed=2)
    data6 = {k: jnp.asarray(v) for k, v in ds6.train.items()}
    st6 = init_sim_state(sim6, strat, x0, placement=pl)
    for leaf in jax.tree.leaves(st6["pms"]):
        assert leaf.sharding.spec[0] is None or \
            len(leaf.sharding.spec) == 0, leaf.sharding.spec
    rf6 = make_round_fn(sim6, strat, grad_fn, data6, placement=pl)
    st6, m6 = rf6(st6)
    assert np.isfinite(float(m6["local_loss"]))

    # rules-level check of the same fallback (param_specs client axis)
    shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((6,) + l.shape, l.dtype), x0)
    specs = rules.param_specs(shapes, mesh, model="model", fsdp=None,
                              client="data")
    for s in jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "spec")):
        assert s.spec[0] is None or len(s.spec) == 0, s.spec

    print("MESH_PLACEMENT_4DEV_OK")
""")


def test_mesh_placement_4device_emulation():
    """4-way client axis: vmap/mesh equivalence at the documented
    tolerance, stores sharded over the client axis, one collective per
    round, and the n-does-not-divide fallback (satellite coverage)."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         env=_SUBPROC_ENV, timeout=560)
    assert "MESH_PLACEMENT_4DEV_OK" in out.stdout, (out.stdout[-1000:],
                                                    out.stderr[-3000:])
