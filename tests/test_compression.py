"""Uplink-compression subsystem (repro/comm + engine threading).

Pins the contracts DESIGN.md §8 records: the identity compressor is
bitwise the uncompressed engine on BOTH placements; quantizers obey
their per-leaf-scale error bounds (and fp8 can never overflow to
inf/nan); top-k handles the k=0 / k=all edges exactly; error-feedback
residual rows live in the state's ``ef`` store -- gathered/scattered
with the cohort, surviving donating scan blocks with their sharding
preserved, and keeping the mesh round at exactly ONE cross-client
collective (decompression happens per-client lane, before the psum);
and the async regime's bandwidth model charges compressed bytes."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.comm import (Identity, Quantize, TopK, make_compressor,
                        payload_bytes, uplink_bytes_per_round)
from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedDeper, MeshPlacement, Scaffold,
                        SimConfig, init_async_state, init_sim_state,
                        make_async_round_fn, make_block_fn, make_round_fn,
                        run_rounds)
from repro.data import make_federated_classification
from repro.launch.mesh import make_client_mesh
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST
SIM = SimConfig(n_clients=6, m_sampled=4, tau=2, batch_size=8, seed=5)
STRAT = FedDeper(eta=0.05, rho=0.03, lam=0.5)


def grad_fn(p, mb):
    (l, _), g = jax.value_and_grad(
        lambda p, b: classifier_loss(CFG, p, b), has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def data():
    ds = make_federated_classification(n_clients=6, per_client=32,
                                       split="shards", seed=2)
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(11))


def _run(data, x0, compressor=None, placement=None, rounds=3):
    state = init_sim_state(SIM, STRAT, x0, placement=placement,
                           compressor=compressor)
    rf = make_round_fn(SIM, STRAT, grad_fn, data, placement=placement,
                       compressor=compressor)
    return run_rounds(state, rf, rounds)


def _assert_trees_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


# ------------------------------------------------------ identity pin

def test_identity_bitwise_vmap(data, x0):
    """The comm path with the identity compressor (extra ef/key plumbing
    traced then DCE'd) is bitwise the no-compressor engine."""
    ref, hist_r = _run(data, x0)
    out, hist_o = _run(data, x0, compressor=Identity())
    for key in ("x", "clients", "pms"):
        _assert_trees_equal(ref[key], out[key], key)
    for hr, ho in zip(hist_r, hist_o):
        assert hr == ho


def test_identity_bitwise_mesh(data, x0):
    """Same pin under the mesh placement (1-device mesh == vmap bitwise,
    so identity-on-mesh must equal the uncompressed vmap round too)."""
    ref, _ = _run(data, x0)
    pl = MeshPlacement(make_client_mesh())
    out, _ = _run(data, x0, compressor=Identity(), placement=pl)
    for key in ("x", "clients", "pms"):
        _assert_trees_equal(ref[key], out[key], key)


# ------------------------------------------------------ quantizers

def _tree(key, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": jax.random.normal(k1, (37, 17)) * scale,
            "b": jax.random.normal(k2, (11,)) * scale * 100.0,
            "z": jax.random.normal(k3, (5, 3, 2)) * scale * 1e-3}


def test_q8_roundtrip_error_bound():
    """Stochastic int8 with per-leaf scale: |deq - x| <= scale per
    element, scale = amax(leaf)/127 -- the floor+uniform draw moves a
    value by strictly less than one quantization step."""
    tree = _tree(jax.random.PRNGKey(0))
    dense, ef, _ = Quantize("int8").roundtrip(tree, {},
                                              jax.random.PRNGKey(1))
    assert ef == {}
    for k in tree:
        step = float(jnp.max(jnp.abs(tree[k]))) / 127.0
        err = np.abs(np.asarray(dense[k]) - np.asarray(tree[k]))
        assert err.max() <= step * (1 + 1e-6), (k, err.max(), step)


def test_q8_stochastic_rounding_is_unbiased_ish():
    """Averaged over many draws the stochastic rounding recovers the
    input to well under one deterministic-rounding step."""
    x = {"w": jnp.linspace(-1.0, 1.0, 256).reshape(16, 16)}
    q = Quantize("int8")
    acc = np.zeros((16, 16))
    n = 64
    for i in range(n):
        dense, _, _ = q.roundtrip(x, {}, jax.random.PRNGKey(i))
        acc += np.asarray(dense["w"])
    step = 1.0 / 127.0
    assert np.abs(acc / n - np.asarray(x["w"])).max() < 0.25 * step


def test_fp8_finite_and_bounded():
    """The e4m3 scale maps amax onto 448, so no finite input can
    overflow; error is bounded by the leaf's largest magnitude times the
    e4m3 relative step (2^-3) plus the scale floor."""
    tree = _tree(jax.random.PRNGKey(2), scale=1e4)
    dense, _, _ = Quantize("fp8").roundtrip(tree, {},
                                            jax.random.PRNGKey(3))
    for k in tree:
        d = np.asarray(dense[k])
        assert np.isfinite(d).all(), k
        amax = float(jnp.max(jnp.abs(tree[k])))
        err = np.abs(d - np.asarray(tree[k]))
        assert err.max() <= amax * (2.0 ** -3), (k, err.max())


def test_quantize_kernel_interpret_parity():
    """The Pallas pack kernel in interpret mode is bitwise the jnp
    expression the CPU path uses (one grid step and blocked grids)."""
    from repro.kernels.quantize import LANES, quantize_stochastic_2d
    key = jax.random.PRNGKey(7)
    v = jax.random.uniform(key, (4, LANES), minval=-127.0, maxval=127.0)
    r = jax.random.uniform(jax.random.fold_in(key, 1), (4, LANES))
    oracle = jnp.clip(jnp.floor(v + r), -127.0, 127.0).astype(jnp.int8)
    for block in (4, 2, 1):
        got = quantize_stochastic_2d(v, r, block_rows=block,
                                     interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


# ------------------------------------------------------ top-k edges

def test_topk_k0_sends_nothing():
    """ratio=0 -> k=0: the wire carries zero elements, the whole
    corrected delta lands in the residual."""
    tree = _tree(jax.random.PRNGKey(4))
    ef0 = TopK(0.0).init_residual(tree)
    dense, ef, _ = TopK(0.0).roundtrip(tree, ef0, jax.random.PRNGKey(0))
    for k in tree:
        assert not np.asarray(dense[k]).any(), k
        np.testing.assert_allclose(np.asarray(ef[k]),
                                   np.asarray(tree[k]), rtol=0, atol=0)
    assert TopK(0.0).payload_bytes(tree) == 0


def test_topk_kall_exact_passthrough():
    """ratio=1 -> k=all: exact pass-through of upload + residual, new
    residual identically zero (every leaf keeps all its elements)."""
    tree = _tree(jax.random.PRNGKey(5))
    carried = jax.tree.map(lambda t: 0.25 * jnp.ones_like(t), tree)
    dense, ef, _ = TopK(1.0).roundtrip(tree, carried,
                                       jax.random.PRNGKey(0))
    for k in tree:
        np.testing.assert_array_equal(
            np.asarray(dense[k]), np.asarray(tree[k] + carried[k]), k)
        assert not np.asarray(ef[k]).any(), k


def test_topk_keeps_largest():
    tree = {"w": jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0])}
    dense, ef, _ = TopK(1 / 3).roundtrip(tree, TopK(1 / 3).init_residual(
        tree), jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(dense["w"]),
                               [0.0, -5.0, 0.0, 3.0, 0.0, 0.0])
    np.testing.assert_allclose(np.asarray(ef["w"]),
                               [0.1, 0.0, 0.2, 0.0, -0.05, 0.0],
                               rtol=0, atol=1e-7)


# ------------------------------------------------------ engine threading

def test_ef_store_updates_only_sampled_rows(data, x0):
    """The residual store has n_clients rows; one round touches exactly
    the sampled cohort's rows (the others stay zero)."""
    from repro.core import peek_sampled_clients
    comp = TopK(0.1)
    state = init_sim_state(SIM, STRAT, x0, compressor=comp)
    idx = np.asarray(peek_sampled_clients(state, SIM))
    rf = make_round_fn(SIM, STRAT, grad_fn, data, compressor=comp)
    state, _ = rf(state)
    touched = np.zeros(SIM.n_clients, bool)
    touched[idx] = True
    # residual mass per client over the WHOLE tree: a tiny leaf can have
    # round(0.1 * size) == size kept (zero residual for that leaf), but
    # a sampled client always drops SOME mass at ratio 0.1
    row_mass = np.zeros(SIM.n_clients)
    for leaf in jax.tree.leaves(state["ef"]):
        leaf = np.asarray(leaf)
        row_mass += np.abs(leaf.reshape(SIM.n_clients, -1)).sum(1)
    assert (row_mass[touched] > 0).all()
    assert (row_mass[~touched] == 0).all()


def test_stateful_compressor_requires_matching_init(data, x0):
    state = init_sim_state(SIM, STRAT, x0)  # no ef store
    rf = make_round_fn(SIM, STRAT, grad_fn, data, compressor=TopK(0.1))
    with pytest.raises(ValueError, match="error-feedback"):
        rf(state)


def test_block_scan_bitwise_with_ef(data, x0):
    """topk + error feedback through the donating scan block: the block
    trajectory (state AND the ef store) is bitwise the host loop's --
    the residual rows thread the carry like the client/pms stores."""
    comp = TopK(0.25)
    loop, _ = _run(data, x0, compressor=comp, rounds=4)
    state = init_sim_state(SIM, STRAT, x0, compressor=comp)
    bf = make_block_fn(SIM, STRAT, grad_fn, data, block_size=2,
                       compressor=comp)
    for _ in range(2):
        state, _ = bf(state)
    for key in ("x", "clients", "pms", "ef"):
        _assert_trees_equal(loop[key], state[key], key)


def test_mesh_block_donating_keeps_ef_sharding(data, x0):
    """Donating mesh scan block: the ef store comes back laid out over
    the client axis (rules.sim_state_specs covers 'ef'), still alive."""
    comp = TopK(0.25)
    pl = MeshPlacement(make_client_mesh())
    state = init_sim_state(SIM, STRAT, x0, placement=pl, compressor=comp)
    bf = make_block_fn(SIM, STRAT, grad_fn, data, block_size=2,
                       placement=pl, compressor=comp)
    state, metrics = bf(state)
    assert np.isfinite(np.asarray(metrics["local_loss"])).all()
    # a size-1 axis may canonicalize to replicated; the strict 4-way
    # P('data', ...) layout assertion lives in the subprocess test below
    for leaf in jax.tree.leaves(state["ef"]):
        spec = leaf.sharding.spec
        assert len(spec) == 0 or spec[0] in (None, "data"), spec
    assert any(np.asarray(l).any() for l in jax.tree.leaves(state["ef"]))


def test_mesh_compressed_round_has_one_collective(data, x0):
    """Compression must not add collectives: each lane decompresses its
    own upload BEFORE the aggregate's psum (FedDeper and Scaffold's
    two-stream upload alike)."""
    from test_engine_placement import count_collectives
    pl = MeshPlacement(make_client_mesh())
    for strat in (STRAT, Scaffold(eta=0.05)):
        comp = TopK(0.1)
        rf = make_round_fn(SIM, strat, grad_fn, data, placement=pl,
                           donate=False, compressor=comp)
        state = init_sim_state(SIM, strat, x0, placement=pl,
                               compressor=comp)
        assert count_collectives(jax.make_jaxpr(rf)(state).jaxpr) == 1, \
            strat.name


# ------------------------------------------------------ async bandwidth

def test_async_stateful_compressor_requires_matching_init(data, x0):
    """Same contract as the sync guard: an async state initialized
    without the stateful compressor fails with the explicit message,
    not a pytree mismatch inside the jitted dispatch."""
    acfg = AsyncSimConfig(n_clients=6, m_concurrent=4, buffer_size=2,
                          tau=2, batch_size=8, seed=0)
    state = init_async_state(acfg, STRAT, x0)  # no ef store
    arf = make_async_round_fn(acfg, STRAT, grad_fn, data,
                              compressor=TopK(0.1))
    with pytest.raises(ValueError, match="error-feedback"):
        arf(state)


def test_async_bandwidth_charges_compressed_bytes(data, x0):
    """With a bandwidth model, upload time scales with wire bytes: the
    topk run's simulated clock beats the dense run's; residual rows are
    scattered at delivery."""
    times = {}
    for name, comp in (("dense", None), ("topk", TopK(0.1))):
        acfg = AsyncSimConfig(n_clients=6, m_concurrent=4, buffer_size=2,
                              tau=2, batch_size=8, alpha=0.5, delay=2.0,
                              seed=0, bandwidth=50_000.0)
        state = init_async_state(acfg, STRAT, x0, compressor=comp)
        arf = make_async_round_fn(acfg, STRAT, grad_fn, data,
                                  compressor=comp)
        for _ in range(3):
            state, m = arf(state)
        times[name] = m["sim_time"]
        if comp is not None:
            assert any(np.asarray(l).any()
                       for l in jax.tree.leaves(state["ef"]))
    assert times["topk"] < times["dense"]


# ------------------------------------------------------ bytes accounting

def test_payload_bytes_ratios(x0):
    dense = uplink_bytes_per_round(None, STRAT, x0, SIM.m_sampled)
    q8 = uplink_bytes_per_round(Quantize("int8"), STRAT, x0,
                                SIM.m_sampled)
    fp8 = uplink_bytes_per_round(Quantize("fp8"), STRAT, x0,
                                 SIM.m_sampled)
    topk = uplink_bytes_per_round(TopK(0.1), STRAT, x0, SIM.m_sampled)
    assert dense >= 4 * 0.99 * q8 and q8 == fp8
    assert dense >= 4 * topk  # 10% kept at 8B/elem vs 4B dense = 5x
    # scaffold ships {dv, dc}: exactly twice the baseline wire bytes
    assert payload_bytes(None, Scaffold().upload_template(x0)) == \
        2 * payload_bytes(None, FedDeper().upload_template(x0))


def test_make_compressor_specs():
    assert make_compressor("none") is None
    assert make_compressor(None) is None
    assert isinstance(make_compressor("identity"), Identity)
    assert make_compressor("q8").mode == "int8"
    assert make_compressor("fp8").mode == "fp8"
    assert make_compressor("topk:0.25").ratio == 0.25
    with pytest.raises(ValueError):
        make_compressor("gzip")
    with pytest.raises(ValueError):
        make_compressor("topk:1.5")


# ------------------------------------------------- 4-device CPU emulation

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.comm import TopK
    from repro.configs.paper_models import MLP_MNIST
    from repro.core import (FedDeper, SimConfig, MeshPlacement,
                            init_sim_state, make_block_fn, make_round_fn,
                            run_rounds)
    from repro.data import make_federated_classification
    from repro.launch.mesh import make_client_mesh
    from repro.models import classifier_loss, init_classifier

    assert jax.local_device_count() == 4

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(
            lambda p, b: classifier_loss(MLP_MNIST, p, b),
            has_aux=True)(p, mb)
        return l, g

    ds = make_federated_classification(n_clients=8, per_client=32,
                                       split="shards", seed=2)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    x0 = init_classifier(MLP_MNIST, jax.random.PRNGKey(11))
    sim = SimConfig(n_clients=8, m_sampled=4, tau=2, batch_size=8,
                    seed=5)
    pl = MeshPlacement(make_client_mesh())
    comp = TopK(0.25)

    # the donating scan block vs the host loop, both compressed: same
    # trajectory INCLUDING the distributed ef store, which must come
    # back sharded over the 4-way client axis after every block
    sl = init_sim_state(sim, FedDeper(eta=0.05, rho=0.03, lam=0.5), x0,
                        placement=pl, compressor=comp)
    rf = make_round_fn(sim, FedDeper(eta=0.05, rho=0.03, lam=0.5),
                       grad_fn, data, placement=pl, compressor=comp)
    sl, _ = run_rounds(sl, rf, 4)

    sb = init_sim_state(sim, FedDeper(eta=0.05, rho=0.03, lam=0.5), x0,
                        placement=pl, compressor=comp)
    bf = make_block_fn(sim, FedDeper(eta=0.05, rho=0.03, lam=0.5),
                       grad_fn, data, block_size=2, placement=pl,
                       compressor=comp)
    for _ in range(2):
        sb, metrics = bf(sb)
        for leaf in jax.tree.leaves(sb["ef"]):
            assert leaf.sharding.spec[0] == "data", leaf.sharding.spec
            assert len(leaf.sharding.device_set) == 4
    for key in ("x", "clients", "pms", "ef"):
        for a, b in zip(jax.tree.leaves(sl[key]),
                        jax.tree.leaves(sb[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)
    assert any(np.asarray(l).any() for l in jax.tree.leaves(sb["ef"]))
    print("COMPRESSION_4DEV_OK")
""")


def test_compression_4device_emulation():
    """4-way client axis: error-feedback rows sharded over the axis,
    surviving donating scan blocks bitwise-equal to the host loop."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         env=_SUBPROC_ENV, timeout=560)
    assert "COMPRESSION_4DEV_OK" in out.stdout, (out.stdout[-1000:],
                                                 out.stderr[-3000:])
