import os
import sys

# Tests run on the single real CPU device -- the 512-device XLA_FLAGS
# override belongs to launch/dryrun.py ONLY.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
