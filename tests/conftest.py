import os
import sys

# Tests run on the single real CPU device -- the 512-device XLA_FLAGS
# override belongs to launch/dryrun.py ONLY.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Minimal environment for subprocess-based tests.  The JAX_PLATFORMS pin
# must survive the stripping: without it jax init probes accelerator
# plugins and can block for minutes on CPU-only hosts.
SUBPROC_ENV = {
    "PYTHONPATH": "src",
    "PATH": "/usr/bin:/bin",
    "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
}
