"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import deper_update, flash_attention, gmm


@pytest.mark.parametrize("shape", [(8,), (100,), (130, 33), (4, 7, 9),
                                   (1024,), (2048, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_deper_update_shapes(shape, dtype):
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 5)
    y, v, x, gy, gv = (jax.random.normal(k, shape, jnp.float32).astype(dtype)
                       for k in ks)
    eta, rho = 0.05, 0.013
    y2, v2 = deper_update({"p": y}, {"p": v}, {"p": x}, {"p": gy},
                          {"p": gv}, eta=eta, rho=rho)
    ry, rv = ref.deper_update_ref(
        y.astype(jnp.float32), v.astype(jnp.float32),
        x.astype(jnp.float32), gy.astype(jnp.float32),
        gv.astype(jnp.float32), eta=eta, rho=rho)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y2["p"], np.float32), ry,
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(v2["p"], np.float32), rv,
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 4, 4, 64),    # MHA
    (2, 256, 4, 2, 64),    # GQA
    (1, 128, 8, 1, 32),    # MQA
    (1, 384, 6, 2, 128),   # non-pow2 blocks
])
@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 64, None), (True, None, 50.0),
    (False, None, None),
])
def test_flash_attention_sweep(B, S, H, K, D, causal, window, cap):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, cap=cap)
    r = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
    out = flash_attention(q, k, v)
    r = ref.flash_attention_ref(q, k, v)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("E,T,d,f", [(2, 16, 32, 48), (4, 64, 96, 80),
                                     (8, 128, 256, 128), (3, 40, 56, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(E, T, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = (jax.random.normal(ks[0], (E, T, d)) / np.sqrt(d)).astype(dtype)
    w = jax.random.normal(ks[1], (E, d, f)).astype(dtype)
    out = gmm(x, w)
    r = ref.gmm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


def test_deper_update_in_strategy_matches_plain():
    """FedDeper with use_pallas=True must equal the tree-map path."""
    from repro.core import FedDeper
    rng = jax.random.PRNGKey(4)
    ks = jax.random.split(rng, 4)
    x = {"w": jax.random.normal(ks[0], (33, 17)),
         "b": jax.random.normal(ks[1], (9,))}

    def grad_fn(p, mb):
        loss = sum(jnp.sum(jnp.square(l - mb)) for l in jax.tree.leaves(p))
        return loss, jax.tree.map(lambda l: 2 * (l - mb), p)

    batches = jnp.arange(3, dtype=jnp.float32)  # tau=3 scalar "batches"
    for use_pallas in (False, True):
        strat = FedDeper(eta=0.03, rho=0.01, lam=0.5,
                         use_pallas=use_pallas)
        cs, up, _ = strat.local_round(x, None, strat.client_init(x),
                                      batches, grad_fn)
        if use_pallas:
            got = (cs, up)
        else:
            want = (cs, up)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
