"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (deper_update, deper_update_per_leaf,
                               flash_attention, gmm)
from repro.kernels.tiling import LANES, TreeFlattener, pick_block


@pytest.mark.parametrize("shape", [(8,), (100,), (130, 33), (4, 7, 9),
                                   (1024,), (2048, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_deper_update_shapes(shape, dtype):
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 5)
    y, v, x, gy, gv = (jax.random.normal(k, shape, jnp.float32).astype(dtype)
                       for k in ks)
    eta, rho = 0.05, 0.013
    y2, v2 = deper_update({"p": y}, {"p": v}, {"p": x}, {"p": gy},
                          {"p": gv}, eta=eta, rho=rho)
    ry, rv = ref.deper_update_ref(
        y.astype(jnp.float32), v.astype(jnp.float32),
        x.astype(jnp.float32), gy.astype(jnp.float32),
        gv.astype(jnp.float32), eta=eta, rho=rho)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y2["p"], np.float32), ry,
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(v2["p"], np.float32), rv,
                               rtol=tol, atol=tol)


def _random_tree(key, dtype=jnp.float32):
    """Mixed-shape tree: sizes straddle lane boundaries, incl. a prime-ish
    total so the padded row count exercises the flattener's rounding."""
    ks = jax.random.split(key, 4)
    return {"w1": jax.random.normal(ks[0], (130, 33), jnp.float32
                                    ).astype(dtype),
            "b1": jax.random.normal(ks[1], (9,), jnp.float32).astype(dtype),
            "deep": {"w2": jax.random.normal(ks[2], (4, 7, 9), jnp.float32
                                             ).astype(dtype),
                     "b2": jax.random.normal(ks[3], (2048,), jnp.float32
                                             ).astype(dtype)}}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_deper_update_single_launch_multi_leaf(dtype):
    """The single-launch path (whole tree in one buffer) must match both
    the per-leaf launch reference and the pure-jnp oracle, leaf for
    leaf."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    y, v, x, gy, gv = (_random_tree(k, dtype) for k in ks)
    eta, rho = 0.05, 0.013
    y_s, v_s = deper_update(y, v, x, gy, gv, eta=eta, rho=rho)
    y_l, v_l = deper_update_per_leaf(y, v, x, gy, gv, eta=eta, rho=rho)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    for got, want in ((y_s, y_l), (v_s, v_l)):
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=tol, atol=tol)
    ry, rv = ref.deper_update_ref(
        jax.tree.leaves(y)[0].astype(jnp.float32),
        jax.tree.leaves(v)[0].astype(jnp.float32),
        jax.tree.leaves(x)[0].astype(jnp.float32),
        jax.tree.leaves(gy)[0].astype(jnp.float32),
        jax.tree.leaves(gv)[0].astype(jnp.float32), eta=eta, rho=rho)
    np.testing.assert_allclose(np.asarray(jax.tree.leaves(y_s)[0],
                                          np.float32), ry, rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(jax.tree.leaves(v_s)[0],
                                          np.float32), rv, rtol=tol,
                               atol=tol)


def test_deper_update_lam_emits_mix_and_upload():
    """With lam the same launch emits the round tail; must equal the
    2-output launch composed with tree-map mixing/upload within f32 ulp
    (the two jit graphs may contract mul+add into fma differently, so
    exact bit equality is not guaranteed across graphs -- the same-graph
    bitwise pins live in test_round_engine.py)."""
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    y, v, x, gy, gv = (_random_tree(k) for k in ks)
    eta, rho, lam = 0.05, 0.013, 0.6
    y2, v2 = deper_update(y, v, x, gy, gv, eta=eta, rho=rho)
    y4, v4, mix, up = deper_update(y, v, x, gy, gv, eta=eta, rho=rho,
                                   lam=lam)
    want_mix = jax.tree.map(lambda a, b: (1.0 - lam) * a + lam * b, v2, y2)
    want_up = jax.tree.map(jnp.subtract, y2, x)
    for got, want in ((y4, y2), (v4, v2), (mix, want_mix), (up, want_up)):
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=0)


def test_tree_flattener_roundtrip():
    tree = _random_tree(jax.random.PRNGKey(9), jnp.bfloat16)
    fl = TreeFlattener(tree)
    buf = fl.flatten(tree)
    assert buf.shape == (fl.rows, LANES) and buf.dtype == jnp.float32
    assert fl.rows % fl.block_rows == 0
    back = fl.unflatten(buf)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-2)
    # block-rows rounding: awkward (prime) row counts never degrade the
    # block to 1 -- rows are padded UP to a block multiple instead
    big = {"p": jnp.zeros((523, LANES))}
    fl2 = TreeFlattener(big, block_rows=256)
    assert fl2.block_rows == 256 and fl2.rows == 768


def test_pick_block_divides():
    for n, target in [(392, 256), (1, 256), (128, 256), (523, 256),
                      (96, 40)]:
        b = pick_block(n, target)
        assert 1 <= b <= min(n, target) and n % b == 0


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 4, 4, 64),    # MHA
    (2, 256, 4, 2, 64),    # GQA
    (1, 128, 8, 1, 32),    # MQA
    (1, 384, 6, 2, 128),   # non-pow2 blocks
])
@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 64, None), (True, None, 50.0),
    (False, None, None),
])
def test_flash_attention_sweep(B, S, H, K, D, causal, window, cap):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, cap=cap)
    r = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
    out = flash_attention(q, k, v)
    r = ref.flash_attention_ref(q, k, v)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("E,T,d,f", [(2, 16, 32, 48), (4, 64, 96, 80),
                                     (8, 128, 256, 128), (3, 40, 56, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(E, T, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = (jax.random.normal(ks[0], (E, T, d)) / np.sqrt(d)).astype(dtype)
    w = jax.random.normal(ks[1], (E, d, f)).astype(dtype)
    out = gmm(x, w)
    r = ref.gmm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


def test_deper_update_in_strategy_matches_plain():
    """FedDeper with use_pallas=True must equal the tree-map path."""
    from repro.core import FedDeper
    rng = jax.random.PRNGKey(4)
    ks = jax.random.split(rng, 4)
    x = {"w": jax.random.normal(ks[0], (33, 17)),
         "b": jax.random.normal(ks[1], (9,))}

    def grad_fn(p, mb):
        loss = sum(jnp.sum(jnp.square(l - mb)) for l in jax.tree.leaves(p))
        return loss, jax.tree.map(lambda l: 2 * (l - mb), p)

    batches = jnp.arange(3, dtype=jnp.float32)  # tau=3 scalar "batches"
    for use_pallas in (False, True):
        strat = FedDeper(eta=0.03, rho=0.01, lam=0.5,
                         use_pallas=use_pallas)
        cs, up, _ = strat.local_round(x, None, strat.client_init(x),
                                      batches, grad_fn)
        if use_pallas:
            got = (cs, up)
        else:
            want = (cs, up)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_deper_update_2d_preserves_per_operand_dtypes():
    """y'/upload keep y's dtype and v'/mix keep v's, also when they
    differ (direct 2-D callers may mix precisions; the pytree wrapper
    pre-casts so only this level can catch a regression)."""
    from repro.kernels.deper_update import deper_update_2d
    ks = jax.random.split(jax.random.PRNGKey(10), 5)
    R = 256
    y, x, gy = (jax.random.normal(k, (R, LANES), jnp.float32)
                for k in ks[:3])
    v, gv = (jax.random.normal(k, (R, LANES)).astype(jnp.bfloat16)
             for k in ks[3:])
    y2, v2 = deper_update_2d(y, v, x, gy, gv, eta=0.05, rho=0.01,
                             block_rows=R, interpret=True)
    assert y2.dtype == jnp.float32 and v2.dtype == jnp.bfloat16
    y4, v4, mix, up = deper_update_2d(y, v, x, gy, gv, eta=0.05, rho=0.01,
                                      lam=0.5, block_rows=R, interpret=True)
    assert y4.dtype == jnp.float32 and v4.dtype == jnp.bfloat16
    assert mix.dtype == jnp.bfloat16 and up.dtype == jnp.float32


def test_deper_update_pytree_mixed_dtypes():
    """Pytree-level contract matches the 2-D one: y'/upload keep y's
    leaf dtypes, v'/mix keep v's, also when the two trees differ."""
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    y, x, gy = (_random_tree(k, jnp.float32) for k in ks[:3])
    v, gv = (_random_tree(k, jnp.bfloat16) for k in ks[3:])
    y4, v4, mix, up = deper_update(y, v, x, gy, gv, eta=0.05, rho=0.01,
                                   lam=0.5)
    for leaf in jax.tree.leaves(y4) + jax.tree.leaves(up):
        assert leaf.dtype == jnp.float32
    for leaf in jax.tree.leaves(v4) + jax.tree.leaves(mix):
        assert leaf.dtype == jnp.bfloat16
