"""Scan-compiled round blocks (``engine.make_block_fn``): R rounds in
ONE jitted ``lax.scan`` must reproduce the host loop BITWISE -- the block
splits the round rng keys identically, so the trajectory is the same
stream -- with per-round metrics stacked as (R,) arrays, donation once
per block, eval cadence at block boundaries, and (mesh placement)
exactly one cross-client psum per scanned round (DESIGN.md §7)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.configs.paper_models import MLP_MNIST
from repro.core import (FedAvg, FedDeper, MeshPlacement, SimConfig,
                        init_sim_state, make_block_fn, make_round_fn,
                        run_blocks, run_rounds)
from repro.data import make_federated_classification
from repro.launch.mesh import make_client_mesh
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def data():
    ds = make_federated_classification(n_clients=6, per_client=64,
                                       split="shards", seed=2)
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(11))


SIM = SimConfig(n_clients=6, m_sampled=4, tau=3, batch_size=16, seed=5)

COLLECTIVES = {"psum", "psum2", "all_gather", "all_to_all", "ppermute",
               "pmax", "pmin"}


def count_executed_collectives(jaxpr) -> int:
    """Collectives one EXECUTION of ``jaxpr`` runs: scan bodies count
    once per trip (length x body count), so a block of R scanned rounds
    whose body has one psum reports exactly R."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVES:
            n += 1
        elif eqn.primitive.name == "scan":
            n += eqn.params["length"] * \
                count_executed_collectives(eqn.params["jaxpr"].jaxpr)
        else:
            for v in eqn.params.values():
                if hasattr(v, "eqns"):
                    n += count_executed_collectives(v)
                elif hasattr(v, "jaxpr"):
                    n += count_executed_collectives(v.jaxpr)
    return n


def _loop(strategy, data, x0, placement=None, rounds=6, **kw):
    state = init_sim_state(SIM, strategy, x0, placement=placement)
    rf = make_round_fn(SIM, strategy, grad_fn, data, placement=placement)
    return run_rounds(state, rf, rounds, **kw)


def _blocks(strategy, data, x0, block_size, placement=None, rounds=6,
            **kw):
    state = init_sim_state(SIM, strategy, x0, placement=placement)
    return run_blocks(
        state,
        lambda size: make_block_fn(SIM, strategy, grad_fn, data,
                                   block_size=size, placement=placement),
        rounds, block_size, **kw)


def _assert_state_equal(a, b, keys=("x", "clients", "pms"), atol=0.0):
    for key in keys:
        for la, lb in zip(jax.tree.leaves(a[key]), jax.tree.leaves(b[key])):
            if atol == 0.0:
                np.testing.assert_array_equal(np.asarray(la),
                                              np.asarray(lb), err_msg=key)
            else:
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           atol=atol, rtol=0, err_msg=key)


def _assert_history_equal(hist_a, hist_b):
    assert len(hist_a) == len(hist_b)
    for ra, rb in zip(hist_a, hist_b):
        assert set(ra) == set(rb)
        for k in ra:
            np.testing.assert_array_equal(ra[k], rb[k], err_msg=k)


# -------------------------------------------------- host-loop equivalence

@pytest.mark.parametrize("strategy", [
    FedDeper(eta=0.05, rho=0.03, lam=0.5),
    FedAvg(eta=0.05),
], ids=["feddeper", "fedavg"])
def test_block_scan_bitwise_equals_host_loop(strategy, data, x0):
    """block_size in {1, 3, rounds}: the scanned block replays the host
    loop's rng splits in-graph, so state AND per-round metrics are
    bitwise-identical on the vmap placement (XLA:CPU)."""
    ref, hist = _loop(strategy, data, x0)
    for block_size in (1, 3, 6):
        st, hb = _blocks(strategy, data, x0, block_size)
        _assert_state_equal(ref, st)
        _assert_history_equal(hist, hb)
        assert int(st["round"]) == 6


def test_block_scan_tail_block(data, x0):
    """block_size that does not divide k_rounds: run_blocks compiles one
    tail block (here 6 = 4 + 2) and the trajectory stays bitwise."""
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    ref, hist = _loop(strategy, data, x0)
    st, hb = _blocks(strategy, data, x0, 4)
    _assert_state_equal(ref, st)
    _assert_history_equal(hist, hb)


def test_block_fn_stacks_metrics(data, x0):
    """One block call returns every metric scalar stacked (R,), round r
    of the block at index r -- the host syncs once per block."""
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    _, hist = _loop(strategy, data, x0, rounds=3)
    bf = make_block_fn(SIM, strategy, grad_fn, data, block_size=3)
    _, stacked = bf(init_sim_state(SIM, strategy, x0))
    for k, v in stacked.items():
        assert v.shape == (3,), (k, v.shape)
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray([h[k] for h in hist], v.dtype),
            err_msg=k)


def test_block_eval_cadence_matches_eval_every(data, x0):
    """Eval-at-block-boundary == run_rounds eval_every=block_size: same
    records carry eval keys, with bitwise-equal values."""
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    from repro.core import make_global_eval
    test = {"x": jax.random.normal(jax.random.PRNGKey(0), (64, 784)),
            "y": jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 10)}
    eval_fn = make_global_eval(apply_loss, test, batch=32)
    _, hist = _loop(strategy, data, x0, eval_fn=eval_fn, eval_every=3)
    _, hb = _blocks(strategy, data, x0, 3, eval_fn=eval_fn)
    _assert_history_equal(hist, hb)
    assert "test_acc" in hb[2] and "test_acc" in hb[5]
    assert "test_acc" not in hb[0]


def test_block_donation_semantics(data, x0):
    """donate=True consumes the passed state once per BLOCK (not per
    round); caller-held params survive (init_sim_state copies);
    donate=False leaves the input state alive."""
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    state0 = init_sim_state(SIM, strategy, x0)
    bf = make_block_fn(SIM, strategy, grad_fn, data, block_size=3)
    state1, _ = bf(state0)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(x0))
    with pytest.raises(RuntimeError):
        np.asarray(jax.tree.leaves(state0["x"])[0])

    state0 = init_sim_state(SIM, strategy, x0)
    bf_nd = make_block_fn(SIM, strategy, grad_fn, data, block_size=3,
                          donate=False)
    state2, _ = bf_nd(state0)
    np.asarray(jax.tree.leaves(state0["x"])[0])  # still alive
    _assert_state_equal(state1, state2)


def test_block_fn_rejects_bad_block_size(data, x0):
    strategy = FedAvg(eta=0.05)
    with pytest.raises(ValueError, match="block_size"):
        make_block_fn(SIM, strategy, grad_fn, data, block_size=0)
    with pytest.raises(ValueError, match="block_size"):
        run_blocks({}, lambda s: None, 4, 0)


# ------------------------------------------------------- collective counts

def test_scanned_mesh_block_has_R_psums_for_R_rounds(data, x0):
    """The block scan keeps exactly ONE cross-client psum per round in
    the scanned jaxpr: R executed collectives for an R-round block, i.e.
    one psum in the scan body and none outside it."""
    pl = MeshPlacement(make_client_mesh())
    for strategy in (FedDeper(eta=0.05, rho=0.03, lam=0.5),
                     FedAvg(eta=0.05)):
        state = init_sim_state(SIM, strategy, x0, placement=pl)
        for R in (1, 3):
            bf = make_block_fn(SIM, strategy, grad_fn, data, block_size=R,
                               placement=pl, donate=False)
            jaxpr = jax.make_jaxpr(bf)(state)
            assert count_executed_collectives(jaxpr.jaxpr) == R, \
                (strategy.name, R)


def test_scanned_vmap_block_has_no_collectives(data, x0):
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    state = init_sim_state(SIM, strategy, x0)
    bf = make_block_fn(SIM, strategy, grad_fn, data, block_size=3,
                       donate=False)
    assert count_executed_collectives(jax.make_jaxpr(bf)(state).jaxpr) == 0


def test_mesh_block_bitwise_on_1device_mesh(data, x0):
    """On the container's 1-device mesh the scanned mesh block equals
    both the mesh host loop and the vmap host loop bitwise."""
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    pl = MeshPlacement(make_client_mesh())
    ref_v, _ = _loop(strategy, data, x0)
    ref_m, _ = _loop(strategy, data, x0, placement=pl)
    st, _ = _blocks(strategy, data, x0, 3, placement=pl)
    _assert_state_equal(ref_m, st)
    _assert_state_equal(ref_v, st)


# ------------------------------------------------- 4-device CPU emulation

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.paper_models import MLP_MNIST
    from repro.core import (FedDeper, SimConfig, MeshPlacement,
                            init_sim_state, make_block_fn, make_round_fn,
                            run_blocks, run_rounds)
    from repro.data import make_federated_classification
    from repro.launch.mesh import make_client_mesh
    from repro.models import classifier_loss, init_classifier

    assert jax.local_device_count() == 4

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(
            lambda p, b: classifier_loss(MLP_MNIST, p, b),
            has_aux=True)(p, mb)
        return l, g

    ds = make_federated_classification(n_clients=8, per_client=64,
                                       split="shards", seed=2)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    x0 = init_classifier(MLP_MNIST, jax.random.PRNGKey(11))
    sim = SimConfig(n_clients=8, m_sampled=4, tau=2, batch_size=16,
                    seed=5)
    pl = MeshPlacement(make_client_mesh())
    strat = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    R = 3

    mk = lambda size, **kw: make_block_fn(sim, strat, grad_fn, data,
                                          block_size=size, placement=pl,
                                          **kw)

    # scanned mesh block == mesh host loop BITWISE (same placement, same
    # rng stream), and == vmap host loop at the documented f32 tolerance
    sm, _ = run_rounds(init_sim_state(sim, strat, x0, placement=pl),
                       make_round_fn(sim, strat, grad_fn, data,
                                     placement=pl), R)
    sb, _ = run_blocks(init_sim_state(sim, strat, x0, placement=pl),
                       mk, R, R)
    sv, _ = run_rounds(init_sim_state(sim, strat, x0),
                       make_round_fn(sim, strat, grad_fn, data), R)
    for key in ("x", "clients", "pms"):
        for a, b, c in zip(jax.tree.leaves(sm[key]),
                           jax.tree.leaves(sb[key]),
                           jax.tree.leaves(sv[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)
            np.testing.assert_allclose(np.asarray(b), np.asarray(c),
                                       rtol=0, atol=1e-6, err_msg=key)

    # the sharded client/pms stores thread the scan carry WITHOUT
    # resharding: still P('data', ...) over 4 devices after the block
    for store in ("clients", "pms"):
        for leaf in jax.tree.leaves(sb[store]):
            assert leaf.sharding.spec[0] == "data", (store,
                                                     leaf.sharding.spec)
            assert len(leaf.sharding.device_set) == 4

    # exactly R executed cross-client collectives for an R-round block
    # (one psum in the scanned body, none outside)
    NAMES = {"psum", "psum2", "all_gather", "all_to_all", "ppermute"}
    def count(jx):
        n = 0
        for eqn in jx.eqns:
            if eqn.primitive.name in NAMES:
                n += 1
            elif eqn.primitive.name == "scan":
                n += eqn.params["length"] * count(eqn.params["jaxpr"].jaxpr)
            else:
                for v in eqn.params.values():
                    if hasattr(v, "eqns"):
                        n += count(v)
                    elif hasattr(v, "jaxpr"):
                        n += count(v.jaxpr)
        return n
    st = init_sim_state(sim, strat, x0, placement=pl)
    assert count(jax.make_jaxpr(mk(R, donate=False))(st).jaxpr) == R

    print("BLOCK_SCAN_4DEV_OK")
""")


def test_mesh_block_4device_emulation():
    """4-way client axis: the scanned block == the mesh host loop
    bitwise, == the vmap loop at atol=1e-6, stores stay sharded through
    the scan carry, and the block jaxpr executes exactly R psums."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         env=_SUBPROC_ENV, timeout=560)
    assert "BLOCK_SCAN_4DEV_OK" in out.stdout, (out.stdout[-1000:],
                                                out.stderr[-3000:])
