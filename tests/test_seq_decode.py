"""Sequence-parallel shard_map flash-decode correctness (incl. the
owner-shard local cache update).

In-process we can only build a 1-device mesh (the 512-device override is
dryrun-only), so the multi-shard math runs in a 4-device subprocess with
its own XLA_FLAGS."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.models.attention import decode_attention


def _dense_reference(q, kc, vc, kn, vn, pos, cap=None):
    kc = jax.lax.dynamic_update_slice_in_dim(kc, kn, pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, vn, pos, axis=1)
    return decode_attention(q, kc, vc, valid_len=pos + 1, cap=cap), kc, vc


def test_seq_sharded_decode_single_device_mesh():
    from repro.models.attention import _shard_map_decode
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    B, L, H, K, D = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kc = jax.random.normal(ks[1], (B, L, K, D))
    vc = jax.random.normal(ks[2], (B, L, K, D))
    kn = jax.random.normal(ks[3], (B, 1, K, D))
    vn = jax.random.normal(ks[4], (B, 1, K, D))
    pos = jnp.int32(19)
    with mesh:
        out, kc2, vc2 = jax.jit(lambda *a: _shard_map_decode(
            *a, cap=None,
            seq_shard={"axis": "model", "dp": ("data",), "mesh": mesh}))(
            q, kc, vc, kn, vn, pos)
    want, kw, vw = _dense_reference(q, kc, vc, kn, vn, 19)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(kc2), np.asarray(kw), rtol=1e-6)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.attention import _shard_map_decode, decode_attention
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    B, L, H, K, D = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kc = jax.random.normal(ks[1], (B, L, K, D))
    vc = jax.random.normal(ks[2], (B, L, K, D))
    kn = jax.random.normal(ks[3], (B, 1, K, D))
    vn = jax.random.normal(ks[4], (B, 1, K, D))
    for pos in (0, 17, 40, 63):  # hits different owner shards
        with mesh:
            out, kc2, vc2 = jax.jit(lambda *a: _shard_map_decode(
                *a, cap=50.0,
                seq_shard={"axis": "model", "dp": (), "mesh": mesh}))(
                q, kc, vc, kn, vn, jnp.int32(pos))
        kw = jax.lax.dynamic_update_slice_in_dim(kc, kn, pos, axis=1)
        vw = jax.lax.dynamic_update_slice_in_dim(vc, vn, pos, axis=1)
        want = decode_attention(q, kw, vw, valid_len=jnp.int32(pos + 1),
                                cap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(kc2), np.asarray(kw),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(vc2), np.asarray(vw),
                                   rtol=1e-6)
    print("SEQ_DECODE_OK")
""")


def test_seq_sharded_decode_four_shards():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         env=_SUBPROC_ENV,
                         timeout=560)
    assert "SEQ_DECODE_OK" in out.stdout, out.stderr[-3000:]
