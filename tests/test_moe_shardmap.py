"""Expert-parallel shard_map MoE vs the pjit capacity-dispatch path.

Runs in a 4-device subprocess (2 data x 2 model) with ample capacity so
both formulations route identically."""
import subprocess
import sys
import textwrap

from conftest import SUBPROC_ENV as _SUBPROC_ENV

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["REPRO_MOE_SHARDMAP"] = "0"   # toggled per-call below
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import axis_types_auto, make_mesh, set_mesh
    from repro.configs import get_config
    from repro.models import moe as moe_mod
    from repro.models.moe_shardmap import apply_moe_shardmap

    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              num_experts=4, experts_per_token=2,
                              capacity_factor=8.0, d_model=64, moe_d_ff=32)
    mesh = make_mesh((2, 2), ("data", "model"),
                     axis_types=axis_types_auto(2))
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 4, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3

    with set_mesh(mesh):
        ref_out, ref_aux = jax.jit(
            lambda p, x: moe_mod.apply_moe(cfg, p, x))(params, x)
        sm_out, sm_aux = jax.jit(
            lambda p, x: apply_moe_shardmap(cfg, p, x,
                                            data_axes=("data",)))(params, x)
    np.testing.assert_allclose(np.asarray(sm_out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)
    # per-shard mean-of-load-balance is a different (standard) estimator
    # of the same quantity; expect agreement only to a few percent
    np.testing.assert_allclose(float(sm_aux.load_balance),
                               float(ref_aux.load_balance), rtol=5e-2)
    assert float(sm_aux.dropped_frac) == 0.0
    print("MOE_SHARDMAP_OK")
""")


def test_moe_shardmap_matches_pjit_path():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         env=_SUBPROC_ENV,
                         timeout=560)
    assert "MOE_SHARDMAP_OK" in out.stdout, (out.stdout[-1000:],
                                             out.stderr[-3000:])
