"""Buffered-async regime: degenerate sync equivalence (bit-for-bit),
staleness-discount math, and buffer/straggler semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedAvg, FedDeper, SimConfig,
                        init_async_state, init_sim_state, make_async_round_fn,
                        make_round_fn, run_rounds, staleness_weights)
from repro.data import make_federated_classification
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, m), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def data():
    ds = make_federated_classification(n_clients=8, per_client=64,
                                       split="shards", seed=1)
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(7))


@pytest.mark.parametrize("strategy", [
    FedAvg(eta=0.05),
    FedDeper(eta=0.05, rho=0.03, lam=0.5),
], ids=["fedavg", "feddeper"])
def test_degenerate_async_equals_sync_bitwise(strategy, data, x0):
    """buffer_size = m, delay = 0, alpha = 0: the async machinery must
    reproduce make_round_fn exactly -- same rng draws, same cohort, same
    aggregation path -- for the full state (x, clients, pms)."""
    sim = SimConfig(n_clients=8, m_sampled=4, tau=3, batch_size=16, seed=3)
    s_sync = init_sim_state(sim, strategy, x0)
    rf = make_round_fn(sim, strategy, grad_fn, data)
    for _ in range(3):
        s_sync, _ = rf(s_sync)

    acfg = AsyncSimConfig(n_clients=8, m_concurrent=4, buffer_size=4,
                          tau=3, batch_size=16, alpha=0.0, delay=0.0,
                          seed=3)
    s_async = init_async_state(acfg, strategy, x0)
    arf = make_async_round_fn(acfg, strategy, grad_fn, data)
    for _ in range(3):
        s_async, _ = arf(s_async)

    for key in ("x", "clients", "pms"):
        for a, b in zip(jax.tree.leaves(s_sync[key]),
                        jax.tree.leaves(s_async[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{strategy.name}:{key}")


@pytest.mark.parametrize("strategy", [
    FedAvg(eta=0.05),
    FedDeper(eta=0.05, rho=0.03, lam=0.5),
], ids=["fedavg", "feddeper"])
def test_degenerate_async_mesh_equals_vmap_async_bitwise(strategy, data,
                                                         x0):
    """The same degenerate config (buffer_size = m, delay = 0, alpha = 0)
    routed through the mesh placement on a 1-device mesh: the async
    aggregate takes ``MeshPlacement.aggregate_buffer``'s unweighted pmean
    path (never padded here), which is bit-identical to the vmap
    ``agg_plain`` -- the sync degenerate pin extended to async-on-mesh."""
    from repro.core import MeshPlacement
    from repro.launch.mesh import make_client_mesh

    acfg = AsyncSimConfig(n_clients=8, m_concurrent=4, buffer_size=4,
                          tau=3, batch_size=16, alpha=0.0, delay=0.0,
                          seed=3)
    s_vmap = init_async_state(acfg, strategy, x0)
    arf = make_async_round_fn(acfg, strategy, grad_fn, data)
    pl = MeshPlacement(make_client_mesh())
    s_mesh = init_async_state(acfg, strategy, x0, placement=pl)
    arf_m = make_async_round_fn(acfg, strategy, grad_fn, data,
                                placement=pl)
    for _ in range(3):
        s_vmap, _ = arf(s_vmap)
        s_mesh, _ = arf_m(s_mesh)
    for key in ("x", "clients", "pms"):
        for a, b in zip(jax.tree.leaves(s_vmap[key]),
                        jax.tree.leaves(s_mesh[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{strategy.name}:{key}")
    assert s_mesh["version"] == s_vmap["version"] == 3


def test_staleness_weights_formula():
    w = np.asarray(staleness_weights([0, 1, 3], alpha=1.0))
    np.testing.assert_allclose(w, [1.0, 0.5, 0.25])
    # alpha=0 -> uniform
    np.testing.assert_allclose(np.asarray(staleness_weights([0, 5], 0.0)),
                               [1.0, 1.0])


def test_staleness_discounted_aggregate_known_buffer():
    """Known buffer -> known weighted delta: uploads u0=[1..], u1=[2..]
    with staleness (0, 3) and alpha=1 give weights (1, 1/4), so
    delta = (u0 + u1/4) / (5/4)."""
    x = {"w": jnp.zeros(3)}
    uploads = {"w": jnp.stack([jnp.ones(3), 2.0 * jnp.ones(3)])}
    w = staleness_weights([0, 3], alpha=1.0)
    new_x, _, _ = FedAvg().aggregate(x, {}, uploads, p=1.0, weights=w)
    expect = (1.0 * 1.0 + 0.25 * 2.0) / 1.25
    np.testing.assert_allclose(np.asarray(new_x["w"]),
                               np.full(3, expect), rtol=1e-6)
    # weights=None keeps the plain mean
    new_x, _, _ = FedAvg().aggregate(x, {}, uploads, p=1.0)
    np.testing.assert_allclose(np.asarray(new_x["w"]), np.full(3, 1.5),
                               rtol=1e-6)


def test_straggler_run_produces_staleness_and_trains(data, x0):
    """Heterogeneous delays + small buffer: versions drift past slow
    clients (staleness > 0) while the model still trains to finite loss."""
    acfg = AsyncSimConfig(n_clients=8, m_concurrent=4, buffer_size=2,
                          tau=3, batch_size=16, alpha=0.5, delay=5.0,
                          delay_dist="lognormal", delay_sigma=1.2, seed=3)
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    state = init_async_state(acfg, strategy, x0)
    arf = make_async_round_fn(acfg, strategy, grad_fn, data)
    state, hist = run_rounds(state, arf, 10)
    assert state["round"] == 10 and state["version"] == 10
    assert max(h["staleness_max"] for h in hist) > 0
    assert hist[-1]["sim_time"] > 0
    assert np.isfinite(hist[-1]["local_loss"])
    # sim time is monotone
    times = [h["sim_time"] for h in hist]
    assert all(b >= a for a, b in zip(times, times[1:]))


def test_buffer_semantics_client_exclusivity(data, x0):
    """A client is never concurrently in flight twice, and every
    aggregation consumes exactly buffer_size uploads."""
    acfg = AsyncSimConfig(n_clients=6, m_concurrent=4, buffer_size=3,
                          tau=2, batch_size=8, alpha=0.5, delay=2.0,
                          delay_dist="uniform", seed=0)
    strategy = FedAvg(eta=0.05)
    state = init_async_state(acfg, strategy, x0)
    arf = make_async_round_fn(acfg, strategy, grad_fn, data)
    for _ in range(6):
        in_flight = [s["client"] for s in state["slots"] if s is not None]
        assert len(in_flight) == len(set(in_flight))
        state, _ = arf(state)
        # leftover buffer is strictly below the trigger threshold
        assert len(state["buffer"]) < acfg.buffer_size


def test_alpha_discounts_stale_uploads(data, x0):
    """With identical trajectories, higher alpha shrinks the influence of
    stale uploads: the aggregate with alpha>0 differs from alpha=0 once
    staleness appears, and weights stay in (0, 1]."""
    def run_alpha(alpha):
        acfg = AsyncSimConfig(n_clients=8, m_concurrent=4, buffer_size=2,
                              tau=2, batch_size=8, alpha=alpha, delay=4.0,
                              delay_dist="lognormal", seed=5)
        strategy = FedAvg(eta=0.05)
        state = init_async_state(acfg, strategy, x0)
        arf = make_async_round_fn(acfg, strategy, grad_fn, data)
        state, hist = run_rounds(state, arf, 8)
        return state, hist

    s0, h0 = run_alpha(0.0)
    s1, h1 = run_alpha(2.0)
    assert max(h["staleness_max"] for h in h0) > 0
    d = sum(float(jnp.abs(a - b).sum()) for a, b in
            zip(jax.tree.leaves(s0["x"]), jax.tree.leaves(s1["x"])))
    assert d > 0


def test_async_donate_false_keeps_input_state_usable(data, x0):
    """make_async_round_fn(donate=False) must neither consume the passed
    state nor change a bit of the trajectory vs the donating default."""
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    acfg = AsyncSimConfig(n_clients=8, m_concurrent=4, buffer_size=4,
                          tau=2, batch_size=16, alpha=0.0, delay=0.0,
                          seed=3)
    s_keep = init_async_state(acfg, strategy, x0)
    arf_nd = make_async_round_fn(acfg, strategy, grad_fn, data,
                                 donate=False)
    s1, _ = arf_nd(s_keep)
    # the input state survives a non-donating round
    for leaf in jax.tree.leaves(s_keep["x"]) + jax.tree.leaves(
            s_keep["pms"]):
        assert np.isfinite(np.asarray(leaf)).all()

    s_don = init_async_state(acfg, strategy, x0)
    arf_d = make_async_round_fn(acfg, strategy, grad_fn, data, donate=True)
    s2, _ = arf_d(s_don)
    for key in ("x", "clients", "pms"):
        for a, b in zip(jax.tree.leaves(s1[key]), jax.tree.leaves(s2[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)
