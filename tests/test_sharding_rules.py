"""Sharding-rule unit tests (no multi-device needed: specs are symbolic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import transformer
from repro.sharding import rules


class FakeMesh:
    """Duck-typed mesh carrying names/shape only (rules never touch
    devices beyond axis sizes for spec construction)."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, object)


def _mesh(multi_pod=False):
    if multi_pod:
        return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_pspec_templates():
    sizes = {"data": 16, "model": 16}
    spec = rules.param_pspec([_K("embed")], (256_000, 4096), model="model",
                             fsdp=None, mesh_sizes=sizes)
    assert spec == P("model", None)
    spec = rules.param_pspec([_K("wq")], (4096, 8192), model="model",
                             fsdp="data", mesh_sizes=sizes)
    assert spec == P("data", "model")
    # stacked layer dim gets None
    spec = rules.param_pspec([_K("pattern"), _K("0"), _K("mixer"),
                              _K("wq")], (28, 4096, 8192), model="model",
                             fsdp=None, mesh_sizes=sizes)
    assert spec == P(None, None, "model")
    # moe expert weights: expert-parallel
    spec = rules.param_pspec([_K("ffn"), _K("we_gate")], (64, 512, 128),
                             model="model", fsdp=None, mesh_sizes=sizes)
    assert spec == P("model", None, None)


def _K(key):
    class KObj:
        def __init__(self, k):
            self.key = k
    return KObj(key)


def test_divisibility_fallback():
    sizes = {"data": 16, "model": 16}
    # 24 heads * 64 = 1536 divisible; but a dim of 9 is not
    spec = rules.param_pspec([_K("wq")], (9, 1536), model="model",
                             fsdp="data", mesh_sizes=sizes)
    assert spec == P(None, "model")
    # 10 experts don't divide 16 -> megatron fallback shards the hidden
    # dim ('.') of the expert weight instead
    spec = rules.param_pspec([_K("we_gate")], (10, 512, 64), model="model",
                             fsdp=None, mesh_sizes=sizes)
    assert spec == P(None, None, "model")
    spec = rules.param_pspec([_K("we_down")], (10, 64, 512), model="model",
                             fsdp=None, mesh_sizes=sizes)
    assert spec == P(None, "model", None)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v3-671b",
                                  "jamba-v0.1-52b", "xlstm-125m"])
def test_param_specs_cover_all_leaves(arch):
    cfg = get_config(arch)
    shapes = transformer.param_shapes(cfg, jnp.bfloat16)
    mesh = _mesh()
    specs = rules.param_specs(shapes, mesh, model="model", fsdp=None)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(
        specs, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_shapes) == len(flat_specs)
    for leaf, s in zip(flat_shapes, flat_specs):
        assert len([a for a in s.spec if a is not None]) <= len(leaf.shape)


def test_client_store_divisibility_fallback():
    """A client-store leaf whose leading (n_clients) axis does not divide
    the mesh's client axis must come back REPLICATED on that dim -- not
    error (the engine's mesh placement relies on this to run rounds with
    awkward n).  Symbolic check on the 4-way sizes; the end-to-end
    4-device NamedSharding check lives in test_engine_placement.py."""
    sizes4 = {"data": 4, "model": 1}
    # n=6 does not divide the 4-way client axis -> client dim replicated
    spec = rules.client_store_pspec([_K("wq")], (6, 4096, 8192),
                                    client="data", model="model",
                                    fsdp=None, mesh_sizes=sizes4)
    assert spec[0] is None
    # n=8 divides -> client dim sharded, trailing dims per param rules
    spec = rules.client_store_pspec([_K("wq")], (8, 4096, 8192),
                                    client="data", model="model",
                                    fsdp=None, mesh_sizes=sizes4)
    assert spec[0] == "data"
    # ... and the real param_specs path on the 1-device mesh: any n
    # divides 1, so the client axis is assigned and nothing errors
    mesh = _mesh()
    stacked = {"w": jax.ShapeDtypeStruct((5, 16, 8), jnp.float32)}
    specs = rules.param_specs(stacked, mesh, model="model", fsdp=None,
                              client="data")
    assert specs["w"].spec[0] == "data"
    assert all(a in (None, "data") for a in specs["w"].spec)


def test_client_axis_prepended():
    cfg = get_config("llama3.2-3b")
    shapes = transformer.param_shapes(cfg, jnp.bfloat16)
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((16,) + l.shape, l.dtype), shapes)
    mesh = _mesh()
    specs = rules.param_specs(stacked, mesh, model="model", fsdp=None,
                              client="data")
    one = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "spec"))[0]
    assert one.spec[0] == "data"


def test_cache_specs_prefer_heads_else_sequence():
    mesh = _mesh()
    # kv heads divisible by model size (1 here) -> largest trailing dim
    cache = {"k": jax.ShapeDtypeStruct((128, 32768, 8, 128), jnp.bfloat16)}
    specs = rules.cache_specs(cache, mesh, model="model", dp=("data",))
    s = specs["k"].spec
    assert s[0] == "data"  # batch over dp
    assert "model" in tuple(a for a in s if a)  # some dim model-sharded
