"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=256,
<=4 experts) runs one forward/train step + prefill/decode on CPU, asserting
output shapes and no NaNs.  Also checks decode-vs-train logit consistency
per architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.core import FedDeper, make_round_step
from repro.models import (decode_step, init_cache, init_model, loss_fn,
                          prefill)


# compile-heavy reduced variants (tens of seconds each on CPU): their
# train-step smoke runs only in the full (`-m ""`) suite; prefill/decode
# coverage for them stays in the quick suite
_HEAVY_ARCHS = {"deepseek-v3-671b", "jamba-v0.1-52b"}


def _mark_heavy(archs, heavy=_HEAVY_ARCHS):
    return [pytest.param(a, marks=pytest.mark.slow) if a in heavy else a
            for a in archs]


def make_batch(cfg, rng, B=2, S=16):
    ks = jax.random.split(rng, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend is not None:
        batch["frontend"] = 0.02 * jax.random.normal(
            ks[2], (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", _mark_heavy(ALL_ARCHS))
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    rng = jax.random.PRNGKey(0)
    params = init_model(cfg, rng)
    batch = make_batch(cfg, rng)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p: loss_fn(cfg, p, batch), has_aux=True)
    )(params)
    assert jnp.isfinite(loss), metrics
    gnorm = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(1)
    params = init_model(cfg, rng)
    B, S = 2, 12
    batch = make_batch(cfg, rng, B=B, S=S)
    # VLM prefix patches consume cache slots too
    extra = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encdec) \
        else 0
    cache = init_cache(cfg, B, S + 4 + extra)
    logits, cache = jax.jit(lambda p, b, c: prefill(cfg, p, b, c))(
        params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = S + (cfg.frontend_tokens if (cfg.frontend and not cfg.is_encdec)
               else 0)
    logits2, cache = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q)
                             )(params, cache, tok, jnp.int32(pos))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma2-9b",
                                  "deepseek-v3-671b", "jamba-v0.1-52b",
                                  "xlstm-125m", "granite-moe-3b-a800m"])
def test_decode_matches_train_forward(arch):
    """Prefill S-1 tokens then decode token S-1; logits must match the
    full-sequence forward at the last position (cache correctness)."""
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(2)
    params = init_model(cfg, rng)
    B, S = 1, 10
    batch = make_batch(cfg, rng, B=B, S=S)

    from repro.models.transformer import (_embed_tokens, _lm_logits,
                                          run_decoder)
    from repro.models.common import rmsnorm, softcap

    # full forward logits at last position
    x = _embed_tokens(cfg, params, batch["tokens"])
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, _, _ = run_decoder(cfg, params, x, positions=positions, mode="train")
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    full_logits = softcap(_lm_logits(cfg, params, h[:, -1:]),
                          cfg.logit_softcap)

    # prefill S-1 then decode the last token
    pre = {k: (v[:, :S - 1] if k != "frontend" else v)
           for k, v in batch.items()}
    cache = init_cache(cfg, B, S)
    _, cache = prefill(cfg, params, pre, cache)
    dec_logits, _ = decode_step(cfg, params, cache,
                                batch["tokens"][:, S - 1:S],
                                jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "granite-moe-3b-a800m",
                                  "xlstm-125m"])
def test_datacenter_round_step(arch):
    """FedDeper round step on reduced configs: one full round on CPU."""
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(3)
    x = init_model(cfg, rng)
    strat = FedDeper(eta=0.05, rho=0.01, lam=0.5)
    C, tau, b, S = 2, 2, 2, 16
    cs = jax.tree.map(lambda l: jnp.broadcast_to(l, (C,) + l.shape).copy(),
                      strat.client_init(x))
    k1, k2 = jax.random.split(rng)
    batch = {
        "tokens": jax.random.randint(k1, (C, tau, b, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(k2, (C, tau, b, S), 0,
                                     cfg.vocab_size),
    }
    if cfg.frontend is not None:
        batch["frontend"] = jnp.zeros((C, tau, b, cfg.frontend_tokens,
                                       cfg.d_model))
    step = jax.jit(make_round_step(cfg, strat))
    x2, ss, cs2, metrics = step(x, {}, cs, batch)
    assert np.isfinite(float(metrics["local_loss"]))
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(x), jax.tree.leaves(x2)))
    assert moved > 0  # aggregation moved the global model


def test_long_500k_applicability_flags():
    subq = {a for a in ALL_ARCHS if get_config(a).sub_quadratic}
    assert subq == {"xlstm-125m", "jamba-v0.1-52b", "gemma2-9b"}
    for a in subq:
        assert "long_500k" in get_config(a).shapes()
    assert "long_500k" not in get_config("qwen2-72b").shapes()
