"""Fused round engine: every fusion seam pinned to the unfused reference
(twin gradients, single-launch Pallas updates, donated round buffers),
plus the tracked-bench schema and the scanned global eval."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import MLP_MNIST
from repro.core import (FedDeper, Scaffold, SimConfig, init_sim_state,
                        make_global_eval, make_round_fn,
                        peek_sampled_clients, run_rounds, twin_grad_fn)
from repro.data import make_federated_classification
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, m), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def data():
    ds = make_federated_classification(n_clients=6, per_client=64,
                                       split="shards", seed=2)
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(11))


SIM = SimConfig(n_clients=6, m_sampled=4, tau=3, batch_size=16, seed=5)


def _run(strategy, data, x0, gf=grad_fn, donate=True, rounds=3):
    state = init_sim_state(SIM, strategy, x0)
    rf = make_round_fn(SIM, strategy, gf, data, donate=donate)
    return run_rounds(state, rf, rounds)


def _assert_state_equal(a, b, keys=("x", "clients", "pms"), atol=0.0):
    for key in keys:
        for la, lb in zip(jax.tree.leaves(a[key]), jax.tree.leaves(b[key])):
            if atol == 0.0:
                np.testing.assert_array_equal(np.asarray(la),
                                              np.asarray(lb), err_msg=key)
            else:
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           atol=atol, rtol=0, err_msg=key)


# ------------------------------------------------------------- fusion seams

def test_fused_twin_gradients_match_reference(data, x0):
    """fuse_grads + the joint twin-gradient pass must reproduce the
    serial reference within f32 tolerance (bitwise on this backend: the
    joint pass emits the same per-stream subgraphs)."""
    ref, _ = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5, fuse_grads=False),
                  data, x0)
    fused, _ = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5, fuse_grads=True),
                    data, x0, gf=twin_grad_fn(apply_loss))
    _assert_state_equal(ref, fused, atol=1e-6)


def test_fused_without_twin_hook_is_bitwise(data, x0):
    """Without a .twin hook the fused engine still fuses the update but
    computes the same serial gradients: bit-for-bit equal."""
    ref, _ = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5, fuse_grads=False),
                  data, x0)
    fused, _ = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5, fuse_grads=True),
                    data, x0)
    _assert_state_equal(ref, fused)


def test_single_launch_pallas_matches_reference(data, x0):
    """One whole-tree launch per step (+ fused mixing/upload tail on the
    last launch) vs the pure tree-map reference: elementwise f32 with no
    reduction reordered, so bitwise."""
    ref, _ = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5, fuse_grads=False),
                  data, x0)
    sl, _ = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5, use_pallas=True,
                          fuse_grads=True), data, x0)
    _assert_state_equal(ref, sl)


def test_per_leaf_pallas_still_matches_reference(data, x0):
    """The unfused per-leaf launch path (fuse_grads=False escape hatch)
    stays available and equal to the reference."""
    ref, _ = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5, fuse_grads=False),
                  data, x0, rounds=1)
    pl, _ = _run(FedDeper(eta=0.05, rho=0.03, lam=0.5, use_pallas=True,
                          fuse_grads=False), data, x0, rounds=1)
    _assert_state_equal(ref, pl)


def test_twin_grad_fn_equals_serial_calls(x0):
    """twin(y, v, mb) == (grad_fn(y), grad_fn(v)) exactly: the joint loss
    has zero cross-terms."""
    tgf = twin_grad_fn(apply_loss)
    k = jax.random.PRNGKey(3)
    mb = {"x": jax.random.normal(k, (8, 784)),
          "y": jax.random.randint(k, (8,), 0, 10)}
    y = x0
    v = jax.tree.map(lambda t: t * 0.9 + 0.01, x0)
    ly, gy, lv, gv = tgf.twin(y, v, mb)
    ly_s, gy_s = tgf(y, mb)
    lv_s, gv_s = tgf(v, mb)
    np.testing.assert_array_equal(np.asarray(ly), np.asarray(ly_s))
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lv_s))
    for a, b in zip(jax.tree.leaves((gy, gv)), jax.tree.leaves((gy_s, gv_s))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   rtol=0)


# ----------------------------------------------------------------- donation

def test_donation_degenerate_bitwise(data, x0):
    """donate=True must not change a single bit of the round outputs."""
    for strategy in (FedDeper(eta=0.05, rho=0.03, lam=0.5),
                     Scaffold(eta=0.05)):
        plain, _ = _run(strategy, data, x0, donate=False)
        donated, _ = _run(strategy, data, x0, donate=True)
        _assert_state_equal(plain, donated)


def test_donation_leaves_caller_params_alive(data, x0):
    """init_sim_state copies x, so donating rounds never consume the
    caller's own params."""
    state0 = init_sim_state(SIM, FedDeper(eta=0.05), x0)
    rf = make_round_fn(SIM, FedDeper(eta=0.05), grad_fn, data)
    state1, _ = rf(state0)
    # x0 still readable after its derived state was donated
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(x0))
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(state1["x"]))
    # and the donated input state really was consumed on this backend
    with pytest.raises(RuntimeError):
        np.asarray(jax.tree.leaves(state0["x"])[0])


# ------------------------------------------------------------ rng contract

def test_peek_sampled_clients_predicts_round_cohort(data, x0):
    """``peek_sampled_clients`` replays the engine's per-round rng split
    layout; if the executor's splits drift, the predicted cohort diverges
    from the one the round actually trains.  Detect the trained cohort
    from which pms rows changed (sampled clients get a fresh personal
    model, unsampled rows are untouched)."""
    strategy = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    rf = make_round_fn(SIM, strategy, grad_fn, data, donate=False)
    state = init_sim_state(SIM, strategy, x0)
    for _ in range(3):  # hold across rounds, not just the seed state
        predicted = sorted(int(c) for c in peek_sampled_clients(state, SIM))
        before = [np.asarray(l) for l in jax.tree.leaves(state["pms"])]
        state, _ = rf(state)
        after = [np.asarray(l) for l in jax.tree.leaves(state["pms"])]
        changed = sorted(
            c for c in range(SIM.n_clients)
            if any((b[c] != a[c]).any() for b, a in zip(before, after)))
        assert changed == predicted
        assert len(predicted) == SIM.m_sampled


# ----------------------------------------------------- scanned global eval

@pytest.mark.parametrize("n_total,batch", [(96, 32), (100, 32), (20, 32)])
def test_global_eval_scores_every_sample(n_total, batch):
    """The scanned eval equals the mean over the FULL split: the trailing
    ``n_total % batch`` rows -- which the old reshape silently dropped
    (100, 32) -- are scored by a separate exact-shape tail call and folded
    in by sample count.  Divisible splits (96, 32) and short splits
    (20, 32) keep the historical batch-mean-of-means bitwise."""
    k = jax.random.PRNGKey(0)
    test = {"x": jax.random.normal(k, (n_total, 784)),
            "y": jax.random.randint(k, (n_total,), 0, 10)}
    x = init_classifier(CFG, jax.random.PRNGKey(1))
    out = make_global_eval(apply_loss, test, batch=batch)({"x": x})

    # reference: one whole-split call (classifier_loss returns per-batch
    # means, so this IS the mean over every held-out sample)
    full_loss, full_m = apply_loss(x, test)
    np.testing.assert_allclose(float(out["test_loss"]), float(full_loss),
                               rtol=1e-5)
    np.testing.assert_allclose(float(out["test_acc"]),
                               float(full_m["acc"]), rtol=1e-5)

    b = min(batch, n_total)
    if n_total % b == 0:
        # divisible: the historical mean of per-batch means (the scanned
        # program is unchanged when there is no remainder; the eager
        # reference loop reassociates by a ulp, hence rtol not bitwise)
        losses, accs = [], []
        for i in range(max(1, n_total // b)):
            mb = {k2: t[i * b:(i + 1) * b] for k2, t in test.items()}
            loss, m = apply_loss(x, mb)
            losses.append(loss)
            accs.append(m["acc"])
        np.testing.assert_allclose(float(out["test_loss"]),
                                   float(jnp.stack(losses).mean()),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(out["test_acc"]),
                                   float(jnp.stack(accs).mean()),
                                   rtol=1e-6)


# ------------------------------------------------------------ tracked bench

def test_round_engine_bench_registered_and_importable():
    """`run.py --only round_engine` must keep resolving: the module
    imports and the registry names it."""
    import inspect

    from benchmarks import round_engine, run
    assert callable(round_engine.round_engine_rows)
    assert "round_engine" in inspect.getsource(run.main)


def test_bench_schema_validator():
    from benchmarks.round_engine import validate_bench
    good = {"b": {"us_per_round": 12.5, "peak_bytes": 1024,
                  "config": {"n": 10}}}
    validate_bench(good)
    for bad in (
        {},
        {"b": {"us_per_round": 0.0, "peak_bytes": 1024, "config": {}}},
        {"b": {"us_per_round": 1.0, "config": {}}},
        {"b": {"us_per_round": 1.0, "peak_bytes": -1, "config": {}}},
        # null peak was tolerated while it came from (CPU-absent) device
        # stats; compiled.memory_analysis() is backend-independent, so
        # null is now a schema error
        {"b": {"us_per_round": 1.0, "peak_bytes": None, "config": {}}},
        {"b": {"us_per_round": 1.0, "peak_bytes": True, "config": {}}},
        {"b": {"us_per_round": 1.0, "peak_bytes": 1024, "config": 3}},
    ):
        with pytest.raises(ValueError):
            validate_bench(bad)


def test_bench_schema_strict_keys_and_comm_rows():
    """Unknown entry keys are schema errors (future bench edits fail
    loudly in the smoke lane), and rows carrying a ``compress`` config
    must track ``uplink_bytes_per_round``."""
    from benchmarks.round_engine import validate_bench
    base = {"us_per_round": 1.0, "peak_bytes": 1024, "config": {}}
    with pytest.raises(ValueError, match="unknown keys"):
        validate_bench({"b": {**base, "stray_field": 1}})
    with pytest.raises(ValueError, match="uplink_bytes_per_round"):
        validate_bench({"b": {**base, "config": {"compress": "q8"}}})
    with pytest.raises(ValueError, match="uplink_bytes_per_round"):
        validate_bench({"b": {**base, "config": {"compress": "q8"},
                              "uplink_bytes_per_round": None}})
    validate_bench({"b": {**base, "config": {"compress": "q8"},
                          "uplink_bytes_per_round": 4096}})


def test_bench_schema_fault_rows():
    """Rows carrying a ``faults`` config must track ``screened_per_round``
    (a non-negative number); fault-free rows must NOT carry it."""
    from benchmarks.round_engine import validate_bench
    base = {"us_per_round": 1.0, "peak_bytes": 1024, "config": {}}
    with pytest.raises(ValueError, match="screened_per_round"):
        validate_bench({"b": {**base, "config": {"faults": "drop:0.2"}}})
    with pytest.raises(ValueError, match="screened_per_round"):
        validate_bench({"b": {**base, "config": {"faults": "drop:0.2"},
                              "screened_per_round": None}})
    with pytest.raises(ValueError, match="screened_per_round"):
        validate_bench({"b": {**base, "config": {"faults": "drop:0.2"},
                              "screened_per_round": -1.0}})
    # screened counts on a fault-free row mean the harness mixed up fns
    with pytest.raises(ValueError, match="no 'faults' spec"):
        validate_bench({"b": {**base, "screened_per_round": 2.0}})
    validate_bench({"b": {**base, "config": {"faults": "drop:0.2"},
                          "screened_per_round": 2.1}})
    validate_bench({"b": {**base, "config": {"faults": "clip:10"},
                          "screened_per_round": 0}})


def test_bench_speedup_regression_gate():
    """check_speedups: fails only when a smoke ratio drops below tol x
    the tracked ratio; missing rows/ratios are skipped."""
    from benchmarks.round_engine import check_speedups
    row = lambda **cfg: {"us_per_round": 1.0, "peak_bytes": 1,  # noqa: E731
                         "config": cfg}
    tracked = {"a": row(speedup_vs_loop=2.0), "b": row(speedup_vs_vmap=1.0)}
    assert check_speedups({"a": row(speedup_vs_loop=1.9)}, tracked) == []
    assert check_speedups({"a": row(speedup_vs_loop=1.01)}, tracked,
                          tol=0.5) == []
    fails = check_speedups({"a": row(speedup_vs_loop=0.9)}, tracked,
                           tol=0.5)
    assert len(fails) == 1 and "speedup_vs_loop" in fails[0]
    # untracked smoke rows and non-ratio config keys are ignored
    assert check_speedups({"c": row(speedup_vs_loop=0.1),
                           "b": row(n=10)}, tracked) == []


def test_checked_in_bench_file_is_valid():
    from benchmarks.round_engine import BENCH_PATH, validate_bench
    obj = json.loads(BENCH_PATH.read_text())
    validate_bench(obj)
    # the tracked headline: the fused engine beats the unfused path
    fused = obj["feddeper_sync_pallas_fused"]["us_per_round"]
    unfused = obj["feddeper_sync_pallas_unfused"]["us_per_round"]
    assert unfused / fused >= 1.3, (unfused, fused)
    # the pallas pair runs the same rounds protocol (like-for-like ratio)
    assert obj["feddeper_sync_pallas_unfused"]["config"]["rounds"] == \
        obj["feddeper_sync_pallas_fused"]["config"]["rounds"]
    # scan-block rows: tracked against the bitwise-identical host loop
    for row in ("feddeper_sync_block4", "feddeper_sync_block12",
                "feddeper_sync_mesh_block4"):
        cfg = obj[row]["config"]
        assert cfg["block_rounds"] >= 1, row
        assert cfg["speedup_vs_loop"] > 0, row
    # comm rows: identity tracks the dense wire cost; the real
    # compressors ship >=4x fewer bytes per round (q8 is 3.9996x --
    # 1 byte/elem + one f32 scale per leaf -- topk:0.1 is 5x)
    dense_b = obj["feddeper_sync_identity"]["uplink_bytes_per_round"]
    for row in ("feddeper_sync_q8", "feddeper_sync_topk"):
        assert dense_b >= 3.99 * obj[row]["uplink_bytes_per_round"], row
    # fault row: screening actually fires at drop=0.2/corrupt=0.05, and
    # the tracked eval accuracy stays within 5pp of the clean reference
    # (the tested acceptance bound is 2pp over 24 rounds; the tracked
    # 12-round row gets headroom for timing-protocol noise)
    frow = obj["feddeper_sync_faults"]
    assert frow["screened_per_round"] > 0
    fcfg = frow["config"]
    assert fcfg["faults"] == "drop:0.2,corrupt:0.05"
    assert fcfg["eval_acc"] >= fcfg["eval_acc_clean"] - 0.05, fcfg


@pytest.mark.slow
def test_round_engine_smoke_run(tmp_path):
    """End-to-end smoke of the bench harness at minimal scale."""
    from benchmarks.round_engine import round_engine_rows, validate_bench
    out = tmp_path / "bench.json"
    rows = round_engine_rows(quick=True, rounds=1, reps=1,
                             include=("feddeper_sync_fused",),
                             out_path=out)
    assert len(rows) == 1 and rows[0].startswith("round_engine/")
    validate_bench(json.loads(out.read_text()))


def test_global_eval_rejects_empty_split():
    with pytest.raises(ValueError, match="empty eval split"):
        make_global_eval(apply_loss, {"x": jnp.zeros((0, 784)),
                                      "y": jnp.zeros((0,), jnp.int32)})
