"""Hypothesis property tests on system invariants."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.kernels.ref import deper_update_ref
from repro.models.common import apply_rope, cross_entropy, softcap

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

floats = st.floats(-2.0, 2.0, allow_nan=False)
small_arrays = st.lists(floats, min_size=4, max_size=32).map(
    lambda l: np.array(l, np.float32))


@given(small_arrays, st.floats(0.0, 0.5), st.floats(0.0, 0.3))
def test_deper_update_rho0_is_sgd(a, eta, rho):
    """rho=0: the y-stream reduces to plain SGD on the same gradients."""
    y, v, x = a, a * 0.5, a * 0.25
    gy, gv = a * 0.1, a * 0.2
    y2, v2 = deper_update_ref(y, v, x, gy, gv, eta=eta, rho=0.0)
    np.testing.assert_allclose(y2, y - eta * gy, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(v2, v - eta * gv, rtol=1e-6, atol=1e-6)


@given(small_arrays, st.floats(0.01, 0.3))
def test_deper_update_fixed_point(a, rho):
    """At y = v = x with zero gradients, the update is a fixed point."""
    y2, v2 = deper_update_ref(a, a, a, a * 0, a * 0, eta=0.1, rho=rho)
    np.testing.assert_allclose(y2, a, rtol=1e-6)
    np.testing.assert_allclose(v2, a, rtol=1e-6)


@given(small_arrays, st.floats(0.01, 0.3), st.floats(0.01, 0.5))
def test_deper_update_reflection_direction(a, rho, eta):
    """The regularizer pushes y opposite to the local drift (v - x):
    with zero gradients, (y2 - y) = -rho * ((v - x) + (y - x))."""
    y, v, x = a * 0.3, a, a * 0.1
    y2, _ = deper_update_ref(y, v, x, 0 * a, 0 * a, eta=eta, rho=rho)
    np.testing.assert_allclose(y2 - y, -rho * ((v - x) + (y - x)),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(2, 40), st.integers(0, 1000))
def test_cross_entropy_bounds(n_classes, seed):
    """CE of uniform logits == log(V); CE >= 0 always."""
    rng = np.random.default_rng(seed)
    logits = np.zeros((4, n_classes), np.float32)
    labels = rng.integers(0, n_classes, (4,))
    ce = float(cross_entropy(jnp.asarray(logits), jnp.asarray(labels)))
    np.testing.assert_allclose(ce, np.log(n_classes), rtol=1e-5)
    logits = rng.normal(size=(4, n_classes)).astype(np.float32)
    assert float(cross_entropy(jnp.asarray(logits),
                               jnp.asarray(labels))) >= 0.0


@given(st.integers(1, 64), st.integers(0, 10_000))
def test_rope_preserves_norm(pos, seed):
    """Rotary embedding is a rotation: vector norms are invariant."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(1, 3, 2, 16)).astype(np.float32)
    out = apply_rope(jnp.asarray(x), jnp.full((1, 3), pos), 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(out), axis=-1),
                               np.linalg.norm(x, axis=-1), rtol=1e-4)


@given(st.floats(1.0, 100.0), small_arrays)
def test_softcap_bounds(cap, a):
    """softcap output is bounded by cap and monotone."""
    out = np.asarray(softcap(jnp.asarray(a * 100), cap))
    assert np.all(np.abs(out) <= cap + 1e-5)
    order = np.argsort(a)
    assert np.all(np.diff(out[order]) >= -1e-6)


@given(st.floats(0.05, 10.0), st.integers(0, 20))
def test_dirichlet_alpha_controls_skew(alpha, seed):
    from repro.data import heterogeneity_stats, make_federated_classification
    ds = make_federated_classification(n_clients=8, per_client=128,
                                       split="dirichlet", alpha=alpha,
                                       seed=seed)
    stats = heterogeneity_stats(ds)
    assert 0.0 <= stats["mean_tv"] <= 1.0
    assert ds.train["x"].shape == (8, 128, 784)


@given(st.integers(1, 6), st.integers(0, 100))
def test_aggregation_mean_identity(c, seed):
    """If every client uploads the same delta, x moves by exactly delta."""
    from repro.core import FedAvg
    rng = np.random.default_rng(seed)
    x = {"w": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    delta = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))
    uploads = {"w": jnp.broadcast_to(delta, (c, 5))}
    new_x, _, _ = FedAvg().aggregate(x, {}, uploads, p=1.0)
    np.testing.assert_allclose(np.asarray(new_x["w"]),
                               np.asarray(x["w"] + delta), rtol=1e-5,
                               atol=1e-6)


@given(st.integers(0, 50), st.floats(0.0, 3.0))
def test_staleness_weights_bounded_and_monotone(s, alpha):
    """Async staleness discounts live in (0, 1] and never rank a staler
    upload above a fresher one."""
    from repro.core import staleness_weights
    w = np.asarray(staleness_weights([s, s + 1], alpha))
    assert 0.0 < w[1] <= w[0] <= 1.0


@given(st.integers(2, 8), st.integers(1, 8), st.integers(0, 50))
def test_moe_capacity_positions_unique(e, k, seed):
    """Dispatch positions within each expert must be unique (no token
    overwrites another's slot)."""
    rng = np.random.default_rng(seed)
    T = 16
    flat_e = rng.integers(0, e, (T * k,))
    onehot = np.eye(e, dtype=np.int32)[flat_e]
    pos = np.cumsum(onehot, 0) - onehot
    pos = pos[np.arange(T * k), flat_e]
    for ei in range(e):
        ps = pos[flat_e == ei]
        assert len(set(ps.tolist())) == len(ps)


@given(st.integers(2, 16), st.integers(1, 4), st.integers(8, 64),
       st.integers(0, 99))
def test_moe_sort_positions_equal_cumsum(e, k, t, seed):
    """The sort-based dispatch positions (perf fix P3) must equal the
    one-hot cumsum formulation exactly (stable order = token-major)."""
    rng = np.random.default_rng(seed)
    flat_e = rng.integers(0, e, (t * k,)).astype(np.int32)
    onehot = np.eye(e, dtype=np.int32)[flat_e]
    pos_ref = (np.cumsum(onehot, 0) - onehot)[np.arange(t * k), flat_e]
    order = np.argsort(flat_e, kind="stable")
    sorted_e = flat_e[order]
    counts = np.bincount(flat_e, minlength=e)
    starts = np.cumsum(counts) - counts
    ranks = np.arange(t * k) - starts[sorted_e]
    pos_sort = np.zeros(t * k, np.int64)
    pos_sort[order] = ranks
    np.testing.assert_array_equal(pos_sort, pos_ref)
