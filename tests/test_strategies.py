"""FL strategy behaviour: the paper's equivalences (Remarks 1 & 3) and
convergence on non-i.i.d splits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import MLP_MNIST
from repro.core import (FedAvg, FedDeper, FedProx, Scaffold, SimConfig,
                        init_sim_state, make_global_eval, make_round_fn,
                        run_rounds)
from repro.data import make_federated_classification
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, m), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def ds():
    return make_federated_classification(n_clients=8, per_client=128,
                                         split="shards", seed=1)


@pytest.fixture(scope="module")
def data(ds):
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


def run(strategy, data, rounds=5, tau=5, m=8, n=8, seed=3):
    sim = SimConfig(n_clients=n, m_sampled=m, tau=tau, batch_size=16,
                    seed=seed)
    x0 = init_classifier(CFG, jax.random.PRNGKey(7))
    state = init_sim_state(sim, strategy, x0)
    rf = make_round_fn(sim, strategy, grad_fn, data)
    state, hist = run_rounds(state, rf, rounds)
    return state, hist


def test_feddeper_rho0_equals_fedavg(data):
    """Remark 3: with rho=0 the globalized stream is plain local SGD, so
    FedDeper's uploaded deltas -- hence the global model -- equal FedAvg's."""
    s1, _ = run(FedAvg(eta=0.05), data)
    s2, _ = run(FedDeper(eta=0.05, rho=0.0, lam=0.5), data)
    for a, b in zip(jax.tree.leaves(s1["x"]), jax.tree.leaves(s2["x"])):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_tau1_full_participation_is_centralized_sgd(data):
    """Remark 1: tau=1 + full participation == centralized SGD on the
    concatenated per-client minibatches."""
    strategy = FedAvg(eta=0.05)
    sim = SimConfig(n_clients=8, m_sampled=8, tau=1, batch_size=16, seed=5)
    x0 = init_classifier(CFG, jax.random.PRNGKey(7))
    state = init_sim_state(sim, strategy, x0)
    rf = make_round_fn(sim, strategy, grad_fn, data)

    # reproduce the sampled batches by replaying the same rng stream
    # (BEFORE the round: the donating round_fn consumes the state buffers)
    rng, k_sel, k_batch = jax.random.split(state["rng"], 3)
    idx = jax.random.choice(k_sel, 8, (8,), replace=False)
    n_i = data["x"].shape[1]
    bidx = jax.random.randint(k_batch, (8, 1, 16), 0, n_i)
    xs = jax.vmap(lambda i, bi: data["x"][i][bi])(idx, bidx)[:, 0]
    ys = jax.vmap(lambda i, bi: data["y"][i][bi])(idx, bidx)[:, 0]

    def central_loss(p):
        # mean over clients of per-client loss == FedAvg aggregate direction
        losses = jax.vmap(lambda xb, yb: apply_loss(p, {"x": xb, "y": yb})[0]
                          )(xs, ys)
        return losses.mean()

    g = jax.grad(central_loss)(x0)
    manual = jax.tree.map(lambda p, gi: p - 0.05 * gi, x0, g)
    new_state, _ = rf(state)
    for a, b in zip(jax.tree.leaves(new_state["x"]),
                    jax.tree.leaves(manual)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_all_strategies_converge(data, ds):
    test = {k: jnp.asarray(v) for k, v in ds.test.items()}
    eval_fn = make_global_eval(apply_loss, test)
    for strat in (FedAvg(eta=0.05), FedProx(eta=0.05, mu=1.0),
                  Scaffold(eta=0.05), FedDeper(eta=0.05, rho=0.03)):
        state, hist = run(strat, data, rounds=12, tau=8)
        metrics = eval_fn(state)
        assert metrics["test_acc"] > 0.55, (strat.name, metrics)
        assert np.isfinite(hist[-1]["local_loss"])


def test_feddeper_personalized_state_tracked(data):
    state, _ = run(FedDeper(eta=0.05, rho=0.03, lam=0.5), data, rounds=3)
    v = state["clients"]["v"]
    assert jax.tree.leaves(v)[0].shape[0] == 8
    # v must have moved away from x0 (local information accumulated)
    diff = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(
        jax.tree.map(lambda a, b: a - b[None], state["x"], v)))
    assert diff > 0


def test_scaffold_control_variates_update(data):
    state, _ = run(Scaffold(eta=0.05), data, rounds=3)
    c_norm = sum(float(jnp.abs(l).sum())
                 for l in jax.tree.leaves(state["server"]["c"]))
    assert c_norm > 0  # server control variate moved


def test_scaffold_weighted_aggregate_participation(data):
    """Weighted (staleness-discounted) Scaffold aggregation: the c-update
    scales by the weight-normalized participation p_eff = p * sum(w)/m,
    so the server control variate gains sum_i w_i dc_i / n -- each upload
    contributes exactly its discounted share (padding lanes with w=0
    contribute nothing).  weights=None stays the uniform path bit-for-bit,
    and all-zero weights fall back to the uniform p."""
    strat = Scaffold(eta=0.05)
    x = {"w": jnp.linspace(-1.0, 1.0, 6).reshape(2, 3)}
    m, n = 4, 8
    rng = np.random.default_rng(7)
    uploads = {
        "dv": {"w": jnp.asarray(rng.normal(0, 0.1, (m, 2, 3)), jnp.float32)},
        "dc": {"w": jnp.asarray(rng.normal(0, 0.1, (m, 2, 3)), jnp.float32)},
    }
    p = m / n
    w = jnp.asarray([1.0, 0.5, 0.25, 0.0])

    # weights=None: c == p * mean(dc), bitwise (the historical path)
    _, s_plain, _ = strat.aggregate(x, strat.server_init(x), uploads, p)
    want = p * np.asarray(uploads["dc"]["w"]).mean(0)
    np.testing.assert_allclose(np.asarray(s_plain["c"]["w"]), want,
                               rtol=1e-6, atol=1e-7)

    # weighted: c == sum_i w_i dc_i / n, x == x + weighted_mean(dv)
    x_w, s_w, _ = strat.aggregate(x, strat.server_init(x), uploads, p,
                                  weights=w)
    wn = np.asarray(w)
    want_c = (np.asarray(uploads["dc"]["w"]) * wn[:, None, None]).sum(0) / n
    np.testing.assert_allclose(np.asarray(s_w["c"]["w"]), want_c,
                               rtol=1e-5, atol=1e-7)
    from repro.core import tree_weighted_mean
    want_x = np.asarray(x["w"]) + np.asarray(
        tree_weighted_mean(uploads["dv"], w)["w"])
    np.testing.assert_allclose(np.asarray(x_w["w"]), want_x,
                               rtol=1e-6, atol=1e-7)

    # a zero-weight lane is massless: dropping it changes nothing (the
    # async mesh path's padding invariance, at matching p_eff)
    ups3 = jax.tree.map(lambda t: t[:3], uploads)
    x3, s3, _ = strat.aggregate(x, strat.server_init(x), ups3, 3 / n,
                                weights=w[:3])
    np.testing.assert_allclose(np.asarray(s3["c"]["w"]),
                               np.asarray(s_w["c"]["w"]),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(x3["w"]), np.asarray(x_w["w"]),
                               rtol=1e-6, atol=1e-7)

    # all-zero weights: uniform-mean fallback AND uniform-p fallback
    x0_, s0_, _ = strat.aggregate(x, strat.server_init(x), uploads, p,
                                  weights=jnp.zeros(m))
    np.testing.assert_allclose(np.asarray(s0_["c"]["w"]),
                               np.asarray(s_plain["c"]["w"]),
                               rtol=1e-6, atol=1e-7)
    assert np.isfinite(np.asarray(x0_["w"])).all()


def test_mixing_rate_bounds(data):
    """lambda=1: v reinitialized from y each round (no history kept)."""
    s_half, _ = run(FedDeper(eta=0.05, rho=0.03, lam=0.5), data, rounds=2)
    s_one, _ = run(FedDeper(eta=0.05, rho=0.03, lam=1.0), data, rounds=2)
    d = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(s_half["clients"]["v"]),
        jax.tree.leaves(s_one["clients"]["v"])))
    assert d > 0  # mixing actually changes the personalized stream


def test_feddeper_fp8_uploads_still_converge(data):
    """Beyond-paper: fp8 delta uploads halve all-reduce bytes; rounding
    the deltas must not break convergence (deltas are O(eta*tau*grad),
    well inside e5m2 range)."""
    s_full, _ = run(FedDeper(eta=0.05, rho=0.03, lam=0.5), data, rounds=10)
    s_fp8, _ = run(FedDeper(eta=0.05, rho=0.03, lam=0.5,
                            upload_dtype="float8_e5m2"), data, rounds=10)

    def loss_of(state):
        l, _ = apply_loss(state["x"], {"x": data["x"].reshape(-1, 784),
                                       "y": data["y"].reshape(-1)})
        return float(l)

    lf, l8 = loss_of(s_full), loss_of(s_fp8)
    assert l8 < lf * 1.5 + 0.1, (lf, l8)


def test_feddeper_pallas_matches_reference_local_round():
    """use_pallas=True routes the alternating update through the fused
    deper_update kernel (interpret mode on CPU); one local round on a
    small pytree must match the pure-jnp path."""
    params = {"w": jnp.linspace(-1.0, 1.0, 24).reshape(4, 6),
              "b": jnp.linspace(0.5, -0.5, 6)}
    target = {"w": jnp.ones((4, 6)) * 0.3, "b": jnp.zeros(6)}

    def quad_grad_fn(p, mb):
        def loss(p):
            return sum(jnp.sum((pi - ti) ** 2 * mb["scale"])
                       for pi, ti in zip(jax.tree.leaves(p),
                                         jax.tree.leaves(target)))
        l, g = jax.value_and_grad(loss)(p)
        return l, g

    batches = {"scale": jnp.asarray([1.0, 0.7, 1.3])}  # tau = 3
    cs = {"v": tmap_like(params, 0.9)}
    out = {}
    for use_pallas in (False, True):
        strat = FedDeper(eta=0.05, rho=0.03, lam=0.5,
                         use_pallas=use_pallas)
        new_cs, upload, metrics = strat.local_round(
            params, None, cs, batches, quad_grad_fn)
        out[use_pallas] = (new_cs["v"], upload)
        assert np.isfinite(float(metrics["local_loss"]))
    for ref, ker in zip(jax.tree.leaves(out[False]),
                        jax.tree.leaves(out[True])):
        np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                                   rtol=1e-5, atol=1e-6)


def tmap_like(tree, scale):
    return jax.tree.map(lambda t: t * scale, tree)


def test_feddeper_fp8_e4m3_upload_roundtrip(data):
    """upload_dtype='float8_e4m3fn' quantizes the uploaded deltas to 3
    mantissa bits; the aggregated global model must stay within e4m3
    quantization error of the full-precision run after one round."""
    s_full, _ = run(FedDeper(eta=0.05, rho=0.03, lam=0.5), data, rounds=1)
    s_fp8, _ = run(FedDeper(eta=0.05, rho=0.03, lam=0.5,
                            upload_dtype="float8_e4m3fn"), data, rounds=1)
    x0 = init_classifier(CFG, jax.random.PRNGKey(7))
    for full, fp8, x0l in zip(jax.tree.leaves(s_full["x"]),
                              jax.tree.leaves(s_fp8["x"]),
                              jax.tree.leaves(x0)):
        delta = np.asarray(full) - np.asarray(x0l)
        err = np.abs(np.asarray(fp8) - np.asarray(full))
        # e4m3: 3-bit mantissa -> relative step 2^-3, plus subnormal floor
        tol = np.abs(delta) * 2.0 ** -3 + 2.0 ** -9
        assert (err <= tol + 1e-7).all(), float((err - tol).max())
    # dtype actually reaches the wire: the upload leaves are e4m3
    strat = FedDeper(eta=0.05, rho=0.03, lam=0.5,
                     upload_dtype="float8_e4m3fn")
    x = init_classifier(CFG, jax.random.PRNGKey(7))
    batches = tmap_like({"x": data["x"][0, :8][None].repeat(2, 0),
                         "y": data["y"][0, :8][None].repeat(2, 0)}, 1)
    _, upload, _ = strat.local_round(x, None, strat.client_init(x),
                                    batches, grad_fn)
    for leaf in jax.tree.leaves(upload):
        assert leaf.dtype == jnp.dtype("float8_e4m3fn")


def test_server_momentum_accelerates_or_matches(data):
    """Beyond-paper: server momentum (SlowMo/FedAvgM family) composes with
    FedDeper -- the momentum state accumulates and the run stays stable."""
    s0, h0 = run(FedDeper(eta=0.05, rho=0.03, lam=0.5), data, rounds=10)
    sm, hm = run(FedDeper(eta=0.05, rho=0.03, lam=0.5,
                          server_lr=0.7, server_momentum=0.6),
                 data, rounds=10)
    assert np.isfinite(hm[-1]["local_loss"])
    mu_norm = sum(float(jnp.abs(l).sum())
                  for l in jax.tree.leaves(sm["server"]["mu"]))
    assert mu_norm > 0
    # momentum run must stay in the same loss ballpark (not diverge)
    assert hm[-1]["local_loss"] < h0[-1]["local_loss"] * 3 + 0.5


# -------------------------------------------------------- tree_weighted_mean

def test_tree_weighted_mean_normalizes_weights():
    """Any uniform positive weight vector equals the plain mean, and
    scaling all weights is a no-op."""
    from repro.core import tree_weighted_mean
    t = {"w": jnp.arange(12.0).reshape(4, 3), "b": jnp.linspace(-1, 1, 4)}
    uniform = jax.tree.map(lambda l: l.mean(0), t)
    for scale in (1.0, 2.0, 0.25):
        got = tree_weighted_mean(t, jnp.full(4, scale))
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(uniform)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)
    # non-uniform: matches the hand-computed weighted mean
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    got = tree_weighted_mean(t, w)
    want = (np.asarray(t["w"]) * np.asarray(w)[:, None]).sum(0) / 10.0
    np.testing.assert_allclose(np.asarray(got["w"]), want, rtol=1e-6)
    # scaled weights: identical result
    got2 = tree_weighted_mean(t, w * 7.5)
    np.testing.assert_allclose(np.asarray(got2["w"]), np.asarray(got["w"]),
                               rtol=1e-6)


def test_tree_weighted_mean_fp8_uploads_nonuniform():
    """fp8-e4m3 upload leaves aggregate in f32: the weighted mean of the
    *dequantized* values, exact within f32 arithmetic."""
    from repro.core import tree_weighted_mean
    rng = np.random.default_rng(0)
    vals = rng.normal(0, 0.05, (3, 16)).astype(np.float32)
    q = jnp.asarray(vals).astype(jnp.float8_e4m3fn)
    w = jnp.asarray([1.0, 0.5, 0.25])
    got = tree_weighted_mean({"d": q}, w)["d"]
    assert got.dtype == jnp.float32
    deq = np.asarray(q.astype(jnp.float32))
    want = (deq * np.asarray(w)[:, None]).sum(0) / 1.75
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-7)


def test_tree_weighted_mean_zero_weight_sum_guard():
    """All-zero weights (every upload discounted away) must fall back to
    the uniform mean instead of producing NaN."""
    from repro.core import tree_weighted_mean
    t = {"w": jnp.arange(6.0).reshape(3, 2)}
    got = tree_weighted_mean(t, jnp.zeros(3))["w"]
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(t["w"].mean(0)), rtol=1e-6)
    # ... and stays differentiable-safe under jit
    got_j = jax.jit(lambda w: tree_weighted_mean(t, w))(jnp.zeros(3))["w"]
    np.testing.assert_array_equal(np.asarray(got_j), np.asarray(got))
