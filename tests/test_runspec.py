"""RunSpec/ServeSpec config API: CLI parity pins, JSON overlay,
checkpoint metadata canonicalization, and the unified spec-string
parser's uniform errors."""
import json

import pytest

from repro.configs import RunSpec, ServeSpec
from repro.configs.specs import SpecError, parse_spec


# ---------------------------------------------------------------------------
# RunSpec.from_args: CLI parity
# ---------------------------------------------------------------------------

def test_from_args_empty_is_defaults():
    assert RunSpec.from_args([]) == RunSpec()


# the exact argvs the system tests drive launch/train.py with -- pinned
# so the RunSpec surface can never drift from the CLI the tests exercise
_PINNED_ARGVS = [
    (["--arch", "llama3.2-3b", "--reduced", "--clients", "2", "--tau",
      "2", "--rounds", "3", "--batch", "2", "--seq", "32"],
     dict(arch="llama3.2-3b", reduced=True, clients=2, tau=2, rounds=3,
          batch=2, seq=32)),
    (["--arch", "llama3.2-3b", "--reduced", "--regime", "async",
      "--clients", "4", "--concurrent", "2", "--buffer", "2", "--delay",
      "3", "--tau", "2", "--rounds", "3", "--batch", "2", "--seq", "32",
      "--per-client", "8"],
     dict(arch="llama3.2-3b", reduced=True, regime="async", clients=4,
          concurrent=2, buffer=2, delay=3.0, tau=2, rounds=3, batch=2,
          seq=32, per_client=8)),
    (["--arch", "llama3.2-3b", "--reduced", "--placement", "vmap",
      "--clients", "2", "--tau", "2", "--rounds", "1", "--batch", "2",
      "--seq", "32", "--bandwidth", "1e6"],
     dict(arch="llama3.2-3b", reduced=True, placement="vmap", clients=2,
          tau=2, rounds=1, batch=2, seq=32, bandwidth=1e6)),
    (["--placement", "mesh", "--store", "virtual:recon", "--compress",
      "q8", "--faults", "drop:0.2", "--robust", "median",
      "--block-rounds", "2", "--ckpt-dir", "/tmp/c", "--ckpt-every", "2"],
     dict(placement="mesh", store="virtual:recon", compress="q8",
          faults="drop:0.2", robust="median", block_rounds=2,
          ckpt_dir="/tmp/c", ckpt_every=2)),
]


@pytest.mark.parametrize("argv,expect", _PINNED_ARGVS)
def test_from_args_pins_cli_surface(argv, expect):
    spec = RunSpec.from_args(argv)
    assert spec == RunSpec().replace(**expect)


def test_from_args_json_overlay(tmp_path):
    """--config JSON is the base; explicit flags override field by
    field; unpassed flags must NOT clobber the file's values."""
    p = tmp_path / "run.json"
    p.write_text(json.dumps({"arch": "llama3.2-3b", "reduced": True,
                             "rounds": 40, "eta": 0.1,
                             "store": "virtual:host"}))
    spec = RunSpec.from_args(["--config", str(p), "--rounds", "7"])
    assert spec.rounds == 7            # flag wins
    assert spec.eta == 0.1             # file survives
    assert spec.store == "virtual:host"
    assert spec.reduced is True
    assert spec.tau == RunSpec().tau   # untouched default


def test_json_roundtrip_and_unknown_field(tmp_path):
    spec = RunSpec(rounds=3, compress="topk:0.1", placement="vmap")
    p = tmp_path / "s.json"
    spec.to_json(str(p))
    assert RunSpec.from_json(str(p)) == spec
    p.write_text(json.dumps({"roundz": 3}))
    with pytest.raises(SystemExit, match="unknown field"):
        RunSpec.from_json(str(p))


def test_to_meta_canonicalizes_through_factories():
    """Two spellings of the same config produce the SAME checkpoint
    metadata (resume compatibility goes through the factories, not
    string equality)."""
    a = RunSpec(faults="drop:0.2,corrupt:0", placement="vmap")
    b = RunSpec(faults="drop:0.2", placement="vmap")
    assert a.to_meta() == b.to_meta()
    m = RunSpec().to_meta()
    assert set(m) == {"compress", "faults", "store", "robust"}
    assert m["compress"] == "none" and m["store"] == "dense"


@pytest.mark.parametrize("kw,msg", [
    (dict(bandwidth=1e6), "--regime async"),
    (dict(robust="median"), "--placement"),
    (dict(block_rounds=2), "--placement"),
    (dict(robust="median", placement="mesh", regime="async"), "async"),
    (dict(compress="q8"), "--placement"),
    (dict(strategy="nope"), "unknown strategy"),
    (dict(clip_norm=1.0, regime="async"), "clip-norm"),
])
def test_validate_guard_rails(kw, msg):
    with pytest.raises(SystemExit, match=msg):
        RunSpec(**kw).validate()


def test_validate_passes_known_good():
    RunSpec().validate()
    RunSpec(placement="mesh", store="virtual:recon", compress="q8",
            faults="drop:0.1", robust="median", block_rounds=2).validate()
    RunSpec(regime="async", bandwidth=1e6, compress="fp8",
            faults="deadline:9").validate()


# ---------------------------------------------------------------------------
# unified spec-string parser
# ---------------------------------------------------------------------------

def test_parse_spec_uniform_errors():
    """All four mini-languages share one lexer; its errors name the
    flag, the offending token, and the vocabulary."""
    with pytest.raises(SpecError, match=r"--store.*unknown.*'bogus'"):
        parse_spec("bogus", flag="--store", heads=("dense", "virtual"),
                   head_label="layout")
    with pytest.raises(SpecError, match="empty spec"):
        parse_spec("  ,", flag="--x", heads=("a",))
    with pytest.raises(SpecError, match="at most 1"):
        parse_spec("a:1:2", flag="--x", heads=("a",),
                   arity={"a": (0, 1)})
    with pytest.raises(SpecError, match="at least 1"):
        parse_spec("a", flag="--x", heads=("a",), arity={"a": (1, 1)})
    with pytest.raises(SpecError, match="unknown key"):
        parse_spec("a,zz:1", flag="--x", heads=("a",), keys=("kk",))
    # greedy heads keep colons in the last positional (paths)
    p = parse_spec("shard:/tmp/a:b", flag="--x", heads=("shard",),
                   arity={"shard": (1, 1)}, greedy=("shard",))
    assert p.args == ("/tmp/a:b",)


def test_factories_reject_bad_specs_uniformly():
    """The real factories ride parse_spec: same error shape across
    --store/--compress/--faults/--robust/--weights."""
    from repro.comm import make_compressor
    from repro.core import make_layout
    from repro.faults import make_faults
    from repro.robust import make_robust
    from repro.serve import make_weight_source
    for fn in (make_layout, make_compressor, make_faults, make_robust,
               make_weight_source):
        with pytest.raises(SpecError):
            fn("definitely-not-a-head")


# ---------------------------------------------------------------------------
# ServeSpec
# ---------------------------------------------------------------------------

def test_servespec_from_args_and_overlay(tmp_path):
    assert ServeSpec.from_args([]) == ServeSpec()
    spec = ServeSpec.from_args(
        ["--arch", "llama3.2-3b", "--reduced", "--ckpt-dir", "/tmp/run1",
         "--gen-tokens", "32", "--slots", "2", "--max-len", "64"])
    assert spec == ServeSpec().replace(
        arch="llama3.2-3b", reduced=True, ckpt_dir="/tmp/run1",
        gen_tokens=32, slots=2, max_len=64)
    p = tmp_path / "serve.json"
    p.write_text(json.dumps({"weights": "q8", "slots": 2}))
    spec = ServeSpec.from_args(["--config", str(p), "--slots", "8"])
    assert spec.weights == "q8" and spec.slots == 8


def test_servespec_resolve_weights_sugar():
    assert ServeSpec().resolve_weights() == "init"
    assert ServeSpec(ckpt_dir="/d").resolve_weights() == "ckpt:/d"
    assert ServeSpec(weights="q8", ckpt_dir="/d").resolve_weights() \
        == "q8:ckpt:/d"
    assert ServeSpec(weights="fp8", ckpt_dir="/d").resolve_weights() \
        == "fp8:ckpt:/d"
    # explicit source wins over the sugar
    assert ServeSpec(weights="init:5",
                     ckpt_dir="/d").resolve_weights() == "init:5"


def test_servespec_validate():
    ServeSpec().validate()
    with pytest.raises(SystemExit, match="--max-len"):
        ServeSpec(prompt_len=100, gen_tokens=64, max_len=128).validate()
    with pytest.raises(SystemExit, match="--slots"):
        ServeSpec(slots=0).validate()
    with pytest.raises(SystemExit, match="--prompt-lens"):
        ServeSpec(simulate=True, prompt_lens="4,x").validate()
    assert ServeSpec(prompt_lens="4, 8,12").parsed_prompt_lens() \
        == (4, 8, 12)
    # simulate mode sizes against the WORST simulated prompt
    with pytest.raises(SystemExit, match="--max-len"):
        ServeSpec(simulate=True, prompt_lens="4,120", gen_tokens=32,
                  max_len=128).validate()
