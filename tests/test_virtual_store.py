"""Virtual client store (core/store.py): only the sampled cohort's rows
on device, gathered from / scattered to a pluggable backing tier -- and
the trajectory must be BITWISE the dense engine's on every seam it
crosses (DESIGN.md §11): sync vmap + mesh, scan blocks, compression EF,
fault screening, the async regime, checkpoints.  Device memory is the
point: the n=100k smoke pins peak_bytes at the n=m dense round's scale.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.comm import make_compressor
from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedAvg, FedDeper, Scaffold,
                        SimConfig, VirtualStore, init_async_state,
                        init_sim_state, make_async_round_fn, make_layout,
                        make_round_fn, run_blocks, run_rounds,
                        state_store_bytes)
from repro.core.rounds import make_block_fn
from repro.data import make_federated_classification
from repro.faults import make_faults
from repro.launch.mesh import make_client_mesh
from repro.core.engine import MeshPlacement
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def data():
    ds = make_federated_classification(n_clients=6, per_client=64,
                                       split="shards", seed=2)
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(11))


SIM = SimConfig(n_clients=6, m_sampled=4, tau=3, batch_size=16, seed=5)

COLLECTIVES = {"psum", "psum2", "all_gather", "all_to_all", "ppermute",
               "pmax", "pmin"}


def count_executed_collectives(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVES:
            n += 1
        elif eqn.primitive.name == "scan":
            n += eqn.params["length"] * \
                count_executed_collectives(eqn.params["jaxpr"].jaxpr)
        else:
            for v in eqn.params.values():
                if hasattr(v, "eqns"):
                    n += count_executed_collectives(v)
                elif hasattr(v, "jaxpr"):
                    n += count_executed_collectives(v.jaxpr)
    return n


def _run(strategy, data, x0, *, layout=None, placement=None, rounds=4,
         compressor=None, faults=None):
    state = init_sim_state(SIM, strategy, x0, placement=placement,
                           compressor=compressor, layout=layout)
    rf = make_round_fn(SIM, strategy, grad_fn, data, placement=placement,
                       compressor=compressor, faults=faults, layout=layout)
    hist = []
    for _ in range(rounds):
        state, mets = rf(state)
        hist.append({k: np.asarray(v) for k, v in mets.items()})
    return state, hist


def _store_rows(store, n):
    """Full store contents as host arrays, dense or virtual."""
    if hasattr(store, "gather_rows"):
        return [np.asarray(l) for l in
                jax.tree.leaves(store.gather_rows(np.arange(n)))]
    return [np.asarray(l) for l in jax.tree.leaves(store)]


def _assert_same_trajectory(sa, ha, sb, hb, n=SIM.n_clients):
    for la, lb in zip(jax.tree.leaves(sa["x"]), jax.tree.leaves(sb["x"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for key in ("clients", "pms", "ef"):
        if key not in sa and key not in sb:
            continue
        for la, lb in zip(_store_rows(sa[key], n), _store_rows(sb[key], n)):
            np.testing.assert_array_equal(la, lb)
    for ma, mb in zip(ha, hb):
        assert set(ma) == set(mb)
        for k in ma:
            np.testing.assert_array_equal(ma[k], mb[k])


@pytest.mark.parametrize("tier", ["host", "recon", "shard"])
@pytest.mark.parametrize("strategy", [FedDeper(), FedAvg(), Scaffold()],
                         ids=["feddeper", "fedavg", "scaffold"])
def test_virtual_matches_dense_sync(data, x0, tier, strategy):
    """Every backing tier reproduces the dense vmap engine bitwise:
    global model, full client/pms store contents, metric history."""
    sd, hd = _run(strategy, data, x0)
    sv, hv = _run(strategy, data, x0, layout=make_layout(f"virtual:{tier}"))
    _assert_same_trajectory(sd, hd, sv, hv)


def test_virtual_matches_dense_mesh_one_psum(data, x0):
    """Under the mesh placement the virtual round is bitwise the dense
    mesh round AND still lowers to exactly ONE cross-client collective
    per round -- gathering through the store must not add any."""
    mesh = make_client_mesh()
    layout = make_layout("virtual:host")
    pd = MeshPlacement(mesh)
    pv = MeshPlacement(mesh)
    sd, hd = _run(FedDeper(), data, x0, placement=pd, rounds=3)
    state = init_sim_state(SIM, FedDeper(), x0, placement=pv, layout=layout)
    rf = make_round_fn(SIM, FedDeper(), grad_fn, data, placement=pv,
                       layout=layout)
    jaxpr = rf.trace(state)
    assert count_executed_collectives(jaxpr.jaxpr) == 1
    hv = []
    for _ in range(3):
        state, mets = rf(state)
        hv.append({k: np.asarray(v) for k, v in mets.items()})
    _assert_same_trajectory(sd, hd, state, hv)


def test_virtual_block_matches_dense_loop(data, x0):
    """run_blocks with a virtual layout (K rounds per jitted scan, ONE
    host gather/scatter per block, cohort collisions across the scanned
    rounds) is bitwise the dense per-round host loop."""
    strategy = FedDeper()
    sd = init_sim_state(SIM, strategy, x0)
    rfd = make_round_fn(SIM, strategy, grad_fn, data)
    sd, hist_d = run_rounds(sd, rfd, 6)
    layout = make_layout("virtual:recon")
    sv = init_sim_state(SIM, strategy, x0, layout=layout)
    sv, hist_v = run_blocks(
        sv, lambda size: make_block_fn(SIM, strategy, grad_fn, data,
                                       block_size=size, layout=layout),
        6, 3)
    for la, lb in zip(jax.tree.leaves(sd["x"]), jax.tree.leaves(sv["x"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for la, lb in zip(_store_rows(sd["clients"], SIM.n_clients),
                      _store_rows(sv["clients"], SIM.n_clients)):
        np.testing.assert_array_equal(la, lb)
    np.testing.assert_array_equal(
        np.concatenate([np.atleast_1d(h["local_loss"]) for h in hist_d]),
        np.concatenate([np.atleast_1d(np.asarray(h["local_loss"]))
                        for h in hist_v]))


def test_virtual_matches_dense_compression_ef(data, x0):
    """Stateful top-k compression: the per-client error-feedback
    residual STORE is virtual too, and its rows stay bitwise the dense
    run's across rounds."""
    comp_d, comp_v = make_compressor("topk:0.25"), make_compressor(
        "topk:0.25")
    sd, hd = _run(FedDeper(), data, x0, compressor=comp_d)
    sv, hv = _run(FedDeper(), data, x0, compressor=comp_v,
                  layout=make_layout("virtual:host"))
    assert hasattr(sv["ef"], "gather_rows")
    _assert_same_trajectory(sd, hd, sv, hv)


def test_virtual_matches_dense_faults(data, x0):
    """Fault injection + screening rides the same round rng stream, so
    dropped/corrupted lanes (and the screened counts) are identical."""
    sd, hd = _run(FedDeper(), data, x0,
                  faults=make_faults("drop:0.25,corrupt:0.25"))
    sv, hv = _run(FedDeper(), data, x0,
                  faults=make_faults("drop:0.25,corrupt:0.25"),
                  layout=make_layout("virtual:host"))
    assert any(float(np.sum(h["screened"])) > 0 for h in hd)
    _assert_same_trajectory(sd, hd, sv, hv)


def test_virtual_async_matches_dense(data, x0):
    """The buffered-async regime's dispatch gather / delivery scatter
    route through the store seam: virtual clients+pms reproduce the
    dense async trajectory bitwise."""
    acfg = AsyncSimConfig(n_clients=6, m_concurrent=4, buffer_size=2,
                          tau=3, batch_size=16, alpha=0.5, delay=5.0,
                          seed=3)
    outs = []
    for layout in (None, make_layout("virtual:host")):
        st = init_async_state(acfg, FedDeper(), x0, layout=layout)
        arf = make_async_round_fn(acfg, FedDeper(), grad_fn, data)
        hist = []
        for _ in range(6):
            st, mets = arf(st)
            hist.append({k: float(v) for k, v in mets.items()})
        outs.append((st, hist))
    (sd, hd), (sv, hv) = outs
    assert hasattr(sv["clients"], "gather_rows")
    for la, lb in zip(jax.tree.leaves(sd["x"]), jax.tree.leaves(sv["x"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for key in ("clients", "pms"):
        for la, lb in zip(_store_rows(sd[key], 6), _store_rows(sv[key], 6)):
            np.testing.assert_array_equal(la, lb)
    assert hd == hv


def test_recon_store_bytes_is_o_touched(data, x0):
    """The reconstructible tier materializes NOTHING until a row is
    written: store_bytes starts at 0 and grows with touched rows, never
    approaching the dense footprint for a lightly-sampled population."""
    layout = make_layout("virtual:recon")
    state = init_sim_state(SIM, FedDeper(), x0, layout=layout)
    assert state_store_bytes(state) == 0
    rf = make_round_fn(SIM, FedDeper(), grad_fn, data, layout=layout)
    state, _ = rf(state)
    touched = state_store_bytes(state)
    assert touched > 0
    dense = init_sim_state(SIM, FedDeper(), x0)
    dense_bytes = sum(np.asarray(l).nbytes
                      for k in ("clients", "pms")
                      for l in jax.tree.leaves(dense[k]))
    # one round touches m of n clients: at most m/n of the dense bytes
    assert touched <= dense_bytes * SIM.m_sampled / SIM.n_clients + 1


def test_checkpoint_virtual_shard_resume_bitwise(data, x0, tmp_path):
    """Kill/resume through a sharded virtual checkpoint: stop after 3
    rounds, checkpoint (sidecar shard files, no densification), restore
    into a FRESH process-worth of state, continue -- bitwise the
    uninterrupted run."""
    strategy = FedDeper()

    def fresh(shard_dir):
        layout = make_layout(f"virtual:shard:{shard_dir}")
        st = init_sim_state(SIM, strategy, x0, layout=layout)
        rf = make_round_fn(SIM, strategy, grad_fn, data, layout=layout)
        return st, rf

    s_ref, rf = fresh(tmp_path / "tiers_ref")
    for _ in range(6):
        s_ref, _ = rf(s_ref)

    s1, rf1 = fresh(tmp_path / "tiers_a")
    for _ in range(3):
        s1, _ = rf1(s1)
    ckdir = str(tmp_path / "ck")
    path = save_checkpoint(ckdir, 3, s1, {"store": "virtual:shard"})
    # the sidecar holds shards, the npz holds no densified store rows
    assert (tmp_path / "ck" / "ckpt_00000003.stores").is_dir()
    with np.load(path) as z:
        assert not any(k.startswith("clients/") for k in z.files)

    s2, rf2 = fresh(tmp_path / "tiers_b")
    tmpl = {k: s2[k] for k in ("x", "clients", "pms", "server", "rng")}
    restored, meta = restore_checkpoint(path, tmpl)
    assert meta["store"] == "virtual:shard"
    s2.update(restored)
    s2["round"] = jnp.asarray(3, s2["round"].dtype)
    for _ in range(3):
        s2, _ = rf2(s2)
    for la, lb in zip(jax.tree.leaves(s_ref["x"]),
                      jax.tree.leaves(s2["x"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for la, lb in zip(_store_rows(s_ref["clients"], SIM.n_clients),
                      _store_rows(s2["clients"], SIM.n_clients)):
        np.testing.assert_array_equal(la, lb)


def test_checkpoint_layout_mismatch_fails_fast(x0, tmp_path):
    """Restoring a virtual checkpoint under --store dense (or vice
    versa, or under a different tier) must raise a clear error instead
    of silently densifying or zero-filling the stores."""
    strategy = FedDeper()
    sv = init_sim_state(SIM, strategy, x0,
                        layout=make_layout("virtual:host"))
    pv = save_checkpoint(str(tmp_path), 1,
                         {k: sv[k] for k in ("x", "clients")}, {})
    sd = init_sim_state(SIM, strategy, x0)
    with pytest.raises(ValueError, match="VIRTUAL"):
        restore_checkpoint(pv, {k: sd[k] for k in ("x", "clients")})
    pd = save_checkpoint(str(tmp_path), 2,
                         {k: sd[k] for k in ("x", "clients")}, {})
    with pytest.raises(ValueError, match="DENSE"):
        restore_checkpoint(pd, {"x": sd["x"], "clients": sv["clients"]})
    s_recon = init_sim_state(SIM, strategy, x0,
                             layout=make_layout("virtual:recon"))
    with pytest.raises(ValueError, match="layout mismatch"):
        restore_checkpoint(pv, {"x": sd["x"],
                                "clients": s_recon["clients"]})


def test_packed_topk_matches_reference_with_ties():
    """The single packed-buffer threshold pass is bitwise the per-leaf
    ``lax.top_k`` reference on every leaf -- including crafted |value|
    TIES straddling the k-th position, where both sides must keep the
    lowest flat indices first."""
    tree = {
        "a": jnp.asarray([3.0, -3.0, 3.0, 1.0, -3.0, 0.5]),
        "b": jnp.asarray([[1.0, -1.0], [1.0, 2.0]]),
        "c": jnp.zeros((3,)),
        "d": jnp.asarray(np.random.default_rng(0).normal(
            size=(37,)).astype(np.float32)),
    }
    for ratio in (0.0, 0.1, 1 / 3, 0.5, 1.0):
        comp = make_compressor(f"topk:{ratio}")
        ref = jax.tree.map(comp._sparsify_leaf, tree)
        got = comp._sparsify_packed(tree)
        for lr, lg in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(lr), np.asarray(lg))


def test_validate_bench_requires_store_bytes():
    from benchmarks.round_engine import validate_bench
    row = {"us_per_round": 1.0, "peak_bytes": 10,
           "config": {"store": "virtual:recon"}}
    with pytest.raises(ValueError, match="store_bytes"):
        validate_bench({"v": dict(row)})
    validate_bench({"v": dict(row, store_bytes=123)})
    with pytest.raises(ValueError, match="store_bytes"):
        validate_bench({"d": {"us_per_round": 1.0, "peak_bytes": 10,
                              "config": {}, "store_bytes": 5}})


def test_check_speedups_memory_gate():
    from benchmarks.round_engine import check_speedups
    tracked = {"row": {"us_per_round": 1.0, "peak_bytes": 100,
                       "config": {}}}
    ok = {"row": {"us_per_round": 1.0, "peak_bytes": 140, "config": {}}}
    bad = {"row": {"us_per_round": 1.0, "peak_bytes": 151, "config": {}}}
    assert check_speedups(ok, tracked) == []
    fails = check_speedups(bad, tracked)
    assert len(fails) == 1 and "peak_bytes" in fails[0]


@pytest.mark.bigmem
def test_bigmem_100k_clients_cohort_footprint():
    """n=100k population, m=10 cohort: the virtual round compiles to a
    device footprint within 2x the n=m=10 DENSE round's -- the round
    engine never sees the population size."""
    from benchmarks.common import SyntheticClientData
    n_big, m = 100_000, 10
    src = SyntheticClientData(input_shape=CFG.input_shape,
                              n_clients=n_big, per_client=64, seed=0)
    x0 = init_classifier(CFG, jax.random.PRNGKey(42))
    strategy = FedDeper()

    sim_small = SimConfig(n_clients=m, m_sampled=m, tau=3, batch_size=16,
                          seed=0)
    small = SyntheticClientData(input_shape=CFG.input_shape, n_clients=m,
                                per_client=64, seed=0)
    data_small = {k: jnp.asarray(v)
                  for k, v in small.take(np.arange(m)).items()}
    rf_d = make_round_fn(sim_small, strategy, grad_fn, data_small)
    st_d = init_sim_state(sim_small, strategy, x0)
    compiled = rf_d.lower(st_d).compile()
    ma = compiled.memory_analysis()
    dense_peak = int(ma.temp_size_in_bytes) + int(ma.output_size_in_bytes)

    sim_big = SimConfig(n_clients=n_big, m_sampled=m, tau=3,
                        batch_size=16, seed=0)
    layout = make_layout("virtual:recon")
    st_v = init_sim_state(sim_big, strategy, x0, layout=layout)
    rf_v = make_round_fn(sim_big, strategy, grad_fn, src, layout=layout)
    st_v, _ = rf_v(st_v)
    assert rf_v.peak_bytes is not None
    assert rf_v.peak_bytes <= 2 * dense_peak, \
        f"virtual n=100k peak {rf_v.peak_bytes} > 2x dense n=m " \
        f"peak {dense_peak}"
    # and the backing tier holds only the touched cohort
    touched = state_store_bytes(st_v)
    row_budget = 3 * m  # clients+pms (+slack) rows for one round
    leaf_bytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(x0))
    assert touched <= row_budget * leaf_bytes * 2
