"""Fault injection + screening + crash-safe recovery (ISSUE 7).

Determinism: the fault schedule is a pure function of (seed, round) --
identical across the host loop, scan blocks (K in {1, 3}), and the mesh
placement -- and ``fault_rate=0`` configs trace the exact no-fault
program (bitwise).  Screening rides the round's single cross-client psum
(jaxpr-counted for FedDeper AND Scaffold).  Recovery: RollbackGuard
discards non-finite blocks and retries with a reseeded schedule.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.comm import make_compressor
from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedDeper, MeshPlacement, Scaffold,
                        SimConfig, RollbackGuard, init_async_state,
                        init_sim_state, make_async_round_fn, make_block_fn,
                        make_global_eval, make_round_fn, peek_round_faults,
                        run_blocks, run_rounds, state_is_finite)
from repro.data import make_federated_classification
from repro.faults import (CORRUPT_MODES, FaultConfig, corrupt_payload,
                          make_faults, screen_upload)
from repro.launch.mesh import make_client_mesh
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST

DEPER = FedDeper(eta=0.05, rho=0.03, lam=0.5)


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def ds():
    return make_federated_classification(n_clients=6, per_client=64,
                                         split="shards", seed=2)


@pytest.fixture(scope="module")
def data(ds):
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(11))


SIM = SimConfig(n_clients=6, m_sampled=4, tau=3, batch_size=16, seed=5)

FAULTS = make_faults("drop:0.25,corrupt:0.25")

COLLECTIVES = {"psum", "psum2", "all_gather", "all_to_all", "ppermute",
               "pmax", "pmin"}


def count_collectives(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVES:
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "eqns"):
                n += count_collectives(v)
            elif hasattr(v, "jaxpr"):
                n += count_collectives(v.jaxpr)
    return n


def _leaves_equal(a, b, keys=("x", "clients", "pms"), atol=0.0, msg=""):
    for key in keys:
        for la, lb in zip(jax.tree.leaves(a[key]), jax.tree.leaves(b[key])):
            if atol:
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=0, atol=atol,
                                           err_msg=f"{msg}{key}")
            else:
                np.testing.assert_array_equal(np.asarray(la),
                                              np.asarray(lb),
                                              err_msg=f"{msg}{key}")


# ----------------------------------------------------------- config/parsing

def test_make_faults_parsing_roundtrip():
    cfg = make_faults("drop:0.2,corrupt:0.05,mode:signflip,deadline:3.5")
    assert cfg.drop == 0.2 and cfg.corrupt == 0.05
    assert cfg.corrupt_mode == "signflip" and cfg.deadline == 3.5
    # canonical spec string survives a parse->spec->parse cycle
    assert make_faults(cfg.spec).spec == cfg.spec
    assert make_faults("none") is None
    assert make_faults(None) is None
    assert make_faults("", clip_norm=0.0) is None
    # clip-only config is active (screening without injection)
    clip = make_faults("none", clip_norm=10.0)
    assert clip.active and clip.clip_norm == 10.0
    # deadline-only: inactive for sync, but kept for the async regime
    dl = make_faults("deadline:5")
    assert dl is not None and not dl.active and dl.deadline == 5.0


def test_make_faults_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown key"):
        make_faults("dropp:0.1")
    with pytest.raises(ValueError, match="key:value"):
        make_faults("drop=0.1")
    with pytest.raises(ValueError, match="not in"):
        make_faults("drop:0.1,mode:garbage")
    with pytest.raises(ValueError, match="not in"):
        make_faults("drop:1.5")
    for mode in CORRUPT_MODES:
        assert make_faults(f"corrupt:0.1,mode:{mode}") is not None
    # unknown-key errors enumerate every corrupt mode (stealth included):
    # the CLI user sees the full vocabulary, not just the legal keys
    with pytest.raises(ValueError) as ei:
        make_faults("bogus:1")
    for mode in CORRUPT_MODES:
        assert mode in str(ei.value)


def test_make_faults_stealth_shorthand():
    """Stealth sugar: 'alie:P' == 'corrupt:P,mode:alie' (ditto collude /
    ipflip), with z:Z feeding attack_z; the canonical spec survives a
    roundtrip."""
    from repro.faults import STEALTH_MODES, needs_attack_key
    for mode in STEALTH_MODES:
        cfg = make_faults(f"{mode}:0.2")
        assert cfg.corrupt == 0.2 and cfg.corrupt_mode == mode
        assert needs_attack_key(cfg)
        assert make_faults(cfg.spec).spec == cfg.spec
    cfg = make_faults("alie:0.25,z:2.5,clip:4.0")
    assert cfg.attack_z == 2.5 and cfg.clip_norm == 4.0
    assert make_faults(cfg.spec).spec == cfg.spec
    # non-stealth modes need no attack key (the engine's broadcast
    # operand only appears for stealth configs)
    assert not needs_attack_key(make_faults("corrupt:0.2,mode:signflip"))
    with pytest.raises(ValueError, match="attack_z must be > 0"):
        make_faults("alie:0.2,z:-1")


# --------------------------------------------------------- screening units

def test_screen_upload_zeroes_nonfinite_lanes():
    cfg = FaultConfig(corrupt=0.5)
    up = {"a": jnp.array([1.0, jnp.nan]), "b": jnp.ones(2)}
    clean, w, fm = screen_upload(cfg, up, jnp.asarray(False))
    assert float(w) == 0.0
    assert float(fm["screened"]) == 1.0 and float(fm["dropped"]) == 0.0
    # values zeroed too: 0 * NaN would still poison the psum
    for leaf in jax.tree.leaves(clean):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_screen_upload_dropped_lane():
    clean, w, fm = screen_upload(FaultConfig(drop=0.5),
                                 {"a": jnp.ones(3)}, jnp.asarray(True))
    assert float(w) == 0.0 and float(fm["dropped"]) == 1.0
    np.testing.assert_array_equal(np.asarray(clean["a"]), 0.0)


def test_screen_upload_norm_clip():
    cfg = FaultConfig(clip_norm=5.0)
    up = {"a": jnp.full((4,), 5.0)}  # l2 norm = 10
    clean, w, fm = screen_upload(cfg, up, jnp.asarray(False))
    np.testing.assert_allclose(float(w), 0.5, rtol=1e-6)
    assert float(fm["screened"]) == 0.0
    # under-norm uploads pass with weight exactly 1
    _, w1, _ = screen_upload(cfg, {"a": jnp.ones(4)}, jnp.asarray(False))
    assert float(w1) == 1.0


def test_screen_upload_zero_norm_scale_is_one():
    """The zero-norm edge the clip guard comment pins: an exactly-zero
    upload has sq=0; the 1e-30 floor keeps rsqrt finite and the outer
    min pins the scale to EXACTLY 1.0 -- full weight, values untouched,
    nothing screened.  Dropping either clause of the guard turns this
    lane into inf*0 inside the psum."""
    cfg = FaultConfig(clip_norm=5.0)
    up = {"a": jnp.zeros(4), "b": jnp.zeros((2, 3))}
    clean, w, fm = screen_upload(cfg, up, jnp.asarray(False))
    assert float(w) == 1.0  # exact, not approximately
    assert float(fm["screened"]) == 0.0
    for leaf in jax.tree.leaves(clean):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_screen_upload_clip_composes_with_signflip():
    """clip o signflip: a sign-flipped over-norm upload is CLIPPED
    (weight in (0, 1), values preserved, screened=0), not zeroed -- the
    finite-value gate and the norm clip are independent clauses."""
    cfg = FaultConfig(corrupt=1.0, corrupt_mode="signflip", clip_norm=5.0)
    up = {"a": jnp.full((4,), 5.0)}  # l2 norm 10 -> scale 0.5
    flipped = corrupt_payload(cfg, up, jnp.asarray(True),
                              jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(flipped["a"]), -5.0)
    clean, w, fm = screen_upload(cfg, flipped, jnp.asarray(False))
    np.testing.assert_allclose(float(w), 0.5, rtol=1e-6)
    assert float(fm["screened"]) == 0.0
    # values pass through un-rescaled: the WEIGHT carries the clip
    np.testing.assert_array_equal(np.asarray(clean["a"]),
                                  np.asarray(flipped["a"]))


def test_corrupt_payload_modes():
    from repro.faults import STEALTH_MODES, attack_round_key
    key = jax.random.PRNGKey(0)
    akey = attack_round_key(key)
    up = {"a": jnp.arange(4, dtype=jnp.float32) + 1.0}
    on, off = jnp.asarray(True), jnp.asarray(False)
    for mode in CORRUPT_MODES:
        cfg = FaultConfig(corrupt=1.0, corrupt_mode=mode)
        out_off = corrupt_payload(cfg, up, off, key, akey=akey)
        np.testing.assert_array_equal(np.asarray(out_off["a"]),
                                      np.asarray(up["a"]), err_msg=mode)
    # a stealth mode without the shared key fails loudly, not deep in
    # jax.random with a cryptic NoneType error
    for mode in STEALTH_MODES:
        with pytest.raises(ValueError, match="shared\\s+attack key"):
            corrupt_payload(FaultConfig(corrupt=1.0, corrupt_mode=mode),
                            up, on, key)
    nan = corrupt_payload(FaultConfig(corrupt=1.0), up, on, key)
    assert np.all(np.isnan(np.asarray(nan["a"])))
    sf = corrupt_payload(
        FaultConfig(corrupt=1.0, corrupt_mode="signflip"), up, on, key)
    np.testing.assert_array_equal(np.asarray(sf["a"]),
                                  -np.asarray(up["a"]))
    sc = corrupt_payload(
        FaultConfig(corrupt=1.0, corrupt_mode="scale", corrupt_scale=10.0),
        up, on, key)
    np.testing.assert_allclose(np.asarray(sc["a"]),
                               10.0 * np.asarray(up["a"]), rtol=1e-6)


# ------------------------------------------------- determinism/equivalence

def test_fault_rate_zero_bitwise_both_placements(data, x0):
    """An all-default FaultConfig() is normalized out of the trace: the
    round program -- and therefore the trajectory -- is bitwise the
    no-fault engine's, on vmap AND on the mesh placement."""
    inactive = FaultConfig()
    assert not inactive.active
    for pl in (None, MeshPlacement(make_client_mesh())):
        ref, href = run_rounds(
            init_sim_state(SIM, DEPER, x0, placement=pl),
            make_round_fn(SIM, DEPER, grad_fn, data, placement=pl), 3)
        got, hgot = run_rounds(
            init_sim_state(SIM, DEPER, x0, placement=pl),
            make_round_fn(SIM, DEPER, grad_fn, data, placement=pl,
                          faults=inactive), 3)
        _leaves_equal(ref, got, msg=f"{pl and 'mesh' or 'vmap'}:")
        for hr, hg in zip(href, hgot):
            assert set(hr) == set(hg)  # no screened/dropped keys appear


def test_fault_schedule_identical_across_drivers(data, x0):
    """Same seed + FaultConfig -> the host loop and scan blocks (K=1, 3)
    produce the identical trajectory AND identical per-round
    screened/dropped counts (the schedule is a pure function of
    (seed, round), not of the driver)."""
    ref, hist = run_rounds(
        init_sim_state(SIM, DEPER, x0),
        make_round_fn(SIM, DEPER, grad_fn, data, faults=FAULTS), 6)
    sched = [(h["screened"], h["dropped"]) for h in hist]
    assert sum(s for s, _ in sched) > 0  # the config actually fires
    for k in (1, 3):
        st, hb = run_blocks(
            init_sim_state(SIM, DEPER, x0),
            lambda size: make_block_fn(SIM, DEPER, grad_fn, data,
                                       block_size=size, faults=FAULTS),
            6, k)
        _leaves_equal(ref, st, msg=f"K={k}:")
        assert [(h["screened"], h["dropped"]) for h in hb] == sched


def test_mesh_screened_round_matches_vmap(data, x0):
    """Screened mesh rounds match screened vmap rounds: counts exactly,
    state at 1e-6 (the mesh weighted mean runs dot-then-normalize inside
    the psum; vmap normalizes outside -- same math, f32 reassociation)."""
    pl = MeshPlacement(make_client_mesh())
    faults = make_faults("drop:0.25,corrupt:0.25", clip_norm=10.0)
    sv, hv = run_rounds(
        init_sim_state(SIM, DEPER, x0),
        make_round_fn(SIM, DEPER, grad_fn, data, faults=faults), 4)
    sm, hm = run_rounds(
        init_sim_state(SIM, DEPER, x0, placement=pl),
        make_round_fn(SIM, DEPER, grad_fn, data, placement=pl,
                      faults=faults), 4)
    _leaves_equal(sv, sm, atol=1e-6, msg="mesh:")
    for a, b in zip(hv, hm):
        assert a["screened"] == b["screened"]
        assert a["dropped"] == b["dropped"]


def test_peek_round_faults_matches_execution(data, x0):
    """``peek_round_faults`` replays the executor's draw: the peeked
    dropped/corrupted(nan) counts equal the executed round's metrics."""
    faults = make_faults("drop:0.4,corrupt:0.4")
    state = init_sim_state(SIM, DEPER, x0)
    rf = make_round_fn(SIM, DEPER, grad_fn, data, faults=faults,
                       donate=False)
    for _ in range(4):
        dropped, corrupted = peek_round_faults(state, SIM, faults)
        nd = int(np.asarray(dropped).sum())
        nc = int(np.asarray(corrupted).sum())
        state, m = rf(state)
        assert int(m["dropped"]) == nd
        # nan corruption always screens; dropped lanes screen too
        assert int(m["screened"]) == nd + nc


def test_drop_all_leaves_global_model_unchanged(data, x0):
    """drop=1.0: no lane carries mass -- the global model and server
    state survive the round bitwise, every lane reports dropped."""
    faults = make_faults("drop:1.0")
    state = init_sim_state(SIM, DEPER, x0)
    rf = make_round_fn(SIM, DEPER, grad_fn, data, faults=faults,
                       donate=False)
    out, m = rf(state)
    assert int(m["dropped"]) == SIM.m_sampled
    assert int(m["screened"]) == SIM.m_sampled
    for a, b in zip(jax.tree.leaves(state["x"]), jax.tree.leaves(out["x"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # dropped clients' stores revert: nothing trained this round
    for key in ("clients", "pms"):
        for a, b in zip(jax.tree.leaves(state[key]),
                        jax.tree.leaves(out[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)


@pytest.mark.parametrize("strategy", [
    DEPER, Scaffold(eta=0.05),
], ids=["feddeper", "scaffold"])
def test_screened_mesh_round_has_one_collective(strategy, data, x0):
    """Screening-as-weights keeps the one-psum invariant: the (m,) weight
    vector, the screened/dropped metrics, and (Scaffold) dv/dc all ride
    the round's single cross-client psum."""
    pl = MeshPlacement(make_client_mesh())
    faults = make_faults("drop:0.2,corrupt:0.05", clip_norm=10.0)
    rf = make_round_fn(SIM, strategy, grad_fn, data, placement=pl,
                       faults=faults, donate=False)
    state = init_sim_state(SIM, strategy, x0, placement=pl)
    assert count_collectives(jax.make_jaxpr(rf)(state).jaxpr) == 1


def test_scaffold_p_eff_sees_screened_mass(data, x0):
    """Scaffold under drop=1.0 stays finite and keeps x/server unchanged:
    p_eff picks up the zero screened mass instead of dividing by it."""
    faults = make_faults("drop:1.0")
    strat = Scaffold(eta=0.05)
    state = init_sim_state(SIM, strat, x0)
    out, m = make_round_fn(SIM, strat, grad_fn, data, faults=faults,
                           donate=False)(state)
    assert state_is_finite(out)
    for a, b in zip(jax.tree.leaves(state["server"]),
                    jax.tree.leaves(out["server"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------- convergence under nan

def test_nan_corruption_run_finishes_finite_within_2pct(ds, data, x0):
    """The acceptance run: corrupt=0.05 nan over 24 rounds completes with
    a finite global model within 2% eval accuracy of the clean run."""
    test = {k: jnp.asarray(v) for k, v in ds.test.items()}
    eval_fn = make_global_eval(apply_loss, test)
    clean, _ = run_rounds(
        init_sim_state(SIM, DEPER, x0),
        make_round_fn(SIM, DEPER, grad_fn, data), 24)
    faulty, _ = run_rounds(
        init_sim_state(SIM, DEPER, x0),
        make_round_fn(SIM, DEPER, grad_fn, data,
                      faults=make_faults("corrupt:0.05")), 24)
    assert state_is_finite(faulty)
    acc_clean = float(eval_fn(clean)["test_acc"])
    acc_faulty = float(eval_fn(faulty)["test_acc"])
    assert acc_faulty >= acc_clean - 0.02, (acc_faulty, acc_clean)


def test_wire_bitflip_composes_with_q8(data, x0):
    """'bitflip' + a q8 compressor flips the int8 WIRE codes: damage is
    bounded by the leaf scale, the run stays finite, and nothing is
    screened (bounded Byzantine damage is below any non-finite gate)."""
    faults = make_faults("corrupt:0.5,mode:bitflip,bitflip:0.01")
    comp = make_compressor("q8")
    state, hist = run_rounds(
        init_sim_state(SIM, DEPER, x0, compressor=comp),
        make_round_fn(SIM, DEPER, grad_fn, data, compressor=comp,
                      faults=faults), 3)
    assert state_is_finite(state)
    assert all("screened" in h for h in hist)


def test_nan_corruption_composes_with_topk(data, x0):
    """nan corruption through the TopK(EF) compressor: the screened lane
    never reaches the mean, the run stays finite, and the error-feedback
    store stays finite too (EF reflects what the client sent, pre-wire)."""
    faults = make_faults("drop:0.25,corrupt:0.25")
    comp = make_compressor("topk:0.5")
    state, hist = run_rounds(
        init_sim_state(SIM, DEPER, x0, compressor=comp),
        make_round_fn(SIM, DEPER, grad_fn, data, compressor=comp,
                      faults=faults), 4)
    assert state_is_finite(state)
    for leaf in jax.tree.leaves(state["ef"]):
        assert np.all(np.isfinite(np.asarray(leaf)))
    assert sum(h["screened"] for h in hist) > 0


# ----------------------------------------------------------- async deadline

def _acfg(**kw):
    base = dict(n_clients=8, m_concurrent=4, buffer_size=2, tau=2,
                batch_size=16, alpha=0.5, delay=5.0,
                delay_dist="lognormal", delay_sigma=1.5, seed=3)
    base.update(kw)
    return AsyncSimConfig(**base)


@pytest.fixture(scope="module")
def adata():
    ds8 = make_federated_classification(n_clients=8, per_client=64,
                                        split="shards", seed=1)
    return {k: jnp.asarray(v) for k, v in ds8.train.items()}


def test_async_rejects_sync_fault_classes(adata, x0):
    with pytest.raises(ValueError, match="only deadline faults"):
        make_async_round_fn(_acfg(), DEPER, grad_fn, adata,
                            faults=make_faults("drop:0.2"))


def test_async_deadline_below_every_delay_raises(adata, x0):
    with pytest.raises(ValueError, match="below every client delay"):
        make_async_round_fn(
            _acfg(delay_dist="constant", delay=5.0), DEPER, grad_fn,
            adata, faults=make_faults("deadline:1.0"))


def test_async_deadline_drops_stragglers(adata, x0):
    """A deadline inside the lognormal delay spread: some dispatches time
    out (metrics['dropped'] accumulates), the run stays finite, and the
    simulated clock still advances monotonically."""
    acfg = _acfg()
    arf = make_async_round_fn(acfg, DEPER, grad_fn, adata,
                              faults=make_faults("deadline:6.0"))
    state = init_async_state(acfg, DEPER, x0)
    dropped, t_prev = 0.0, 0.0
    for _ in range(8):
        state, m = arf(state)
        dropped += m["dropped"]
        assert state["t"] >= t_prev
        t_prev = state["t"]
    assert dropped > 0
    assert state_is_finite(state)


def test_async_huge_deadline_is_noop(adata, x0):
    """A deadline above every delay never fires: the trajectory is
    bitwise the no-faults async run's."""
    acfg = _acfg()
    ref = init_async_state(acfg, DEPER, x0)
    arf_ref = make_async_round_fn(acfg, DEPER, grad_fn, adata)
    got = init_async_state(acfg, DEPER, x0)
    arf_got = make_async_round_fn(acfg, DEPER, grad_fn, adata,
                                  faults=make_faults("deadline:1e9"))
    for _ in range(4):
        ref, mr = arf_ref(ref)
        got, mg = arf_got(got)
        assert mg["dropped"] == 0.0
    _leaves_equal(ref, got)
    assert ref["t"] == got["t"] and ref["version"] == got["version"]


# ------------------------------------------------------ crash-safe recovery

def _tiny_state(x_val=1.0):
    return {"x": {"w": jnp.full((2,), x_val)}, "server": {},
            "clients": {}, "pms": {},
            "rng": jax.random.PRNGKey(0), "round": jnp.asarray(0)}


def test_state_is_finite_checks_x_and_server_only():
    s = _tiny_state()
    assert state_is_finite(s)
    s["x"]["w"] = jnp.array([1.0, jnp.nan])
    assert not state_is_finite(s)
    s = _tiny_state()
    s["clients"] = {"c": jnp.array([jnp.inf])}  # client rows don't count
    assert state_is_finite(s)


def test_rollback_guard_restores_and_reseeds():
    good = _tiny_state(1.0)
    guard = RollbackGuard(good, max_retries=3)
    bad = _tiny_state(float("nan"))
    bad["rng"] = good["rng"]
    restored, ok = guard.after(bad)
    assert not ok and guard.rollbacks == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]["w"]),
                                  np.asarray(good["x"]["w"]))
    # the retry draws a DIFFERENT schedule: rng is reseeded, not reused
    assert not np.array_equal(np.asarray(restored["rng"]),
                              np.asarray(good["rng"]))
    # a subsequent good state resets the retry counter and re-snapshots
    ok_state = _tiny_state(2.0)
    out, ok = guard.after(ok_state)
    assert ok and guard.retries == 0 and guard.rollbacks == 1


def test_rollback_guard_bounded_retries():
    guard = RollbackGuard(_tiny_state(1.0), max_retries=2)
    for _ in range(2):
        _, ok = guard.after(_tiny_state(float("nan")))
        assert not ok
    with pytest.raises(RuntimeError, match="non-finite after 2"):
        guard.after(_tiny_state(float("nan")))


def test_run_blocks_guard_discards_and_retries():
    """A block that diverges is discarded: run_blocks re-runs the same
    rounds from the restored state and the history only records accepted
    rounds (plus the guard's rollback tally)."""
    calls = {"n": 0}

    def make_block(size):
        def block(state):
            calls["n"] += 1
            poison = calls["n"] == 2  # second block diverges once
            val = float("nan") if poison else calls["n"]
            out = dict(state)
            out["x"] = {"w": jnp.full((2,), val)}
            return out, {"m": jnp.full((size,), float(calls["n"]))}
        return block

    logged = []
    state, hist = run_blocks(_tiny_state(), make_block, 4, 2,
                             guard=RollbackGuard(_tiny_state(),
                                                 max_retries=3),
                             log=logged.append)
    assert calls["n"] == 3  # 2 accepted blocks + 1 discarded
    assert [h["round"] for h in hist] == [1, 2, 3, 4]
    # the discarded block's metrics never reach the history
    assert [h["m"] for h in hist] == [1.0, 1.0, 3.0, 3.0]
    assert any("rollback" in rec for rec in logged)


def test_guarded_engine_block_recovers(data, x0):
    """End to end with REAL engine state (device arrays, donated block
    buffers): one block's output is poisoned to NaN; the guard discards
    it, restores the snapshot, and the rerun completes all rounds with a
    finite model."""
    from repro.core.strategies import tmap
    calls = {"n": 0}

    def make_block(size):
        inner = make_block_fn(SIM, DEPER, grad_fn, data, block_size=size)

        def block(state):
            calls["n"] += 1
            out, mets = inner(state)
            if calls["n"] == 2:  # simulate an unscreened divergence
                out = dict(out)
                out["x"] = tmap(lambda t: jnp.full_like(t, jnp.nan),
                                out["x"])
            return out, mets
        return block

    guard = RollbackGuard(init_sim_state(SIM, DEPER, x0), max_retries=3)
    state, hist = run_blocks(init_sim_state(SIM, DEPER, x0), make_block,
                             6, 2, guard=guard)
    assert guard.rollbacks == 1
    assert state_is_finite(state)
    assert [h["round"] for h in hist] == list(range(1, 7))


# ----------------------------------------------------- 4-device emulation

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.paper_models import MLP_MNIST
    from repro.core import (FedDeper, Scaffold, SimConfig, MeshPlacement,
                            init_sim_state, make_round_fn, run_rounds)
    from repro.data import make_federated_classification
    from repro.faults import FaultConfig, make_faults
    from repro.launch.mesh import make_client_mesh
    from repro.models import classifier_loss, init_classifier

    assert jax.local_device_count() == 4

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(
            lambda p, b: classifier_loss(MLP_MNIST, p, b),
            has_aux=True)(p, mb)
        return l, g

    ds = make_federated_classification(n_clients=8, per_client=64,
                                       split="shards", seed=2)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    x0 = init_classifier(MLP_MNIST, jax.random.PRNGKey(11))
    sim = SimConfig(n_clients=8, m_sampled=4, tau=2, batch_size=16,
                    seed=5)
    pl = MeshPlacement(make_client_mesh())
    faults = make_faults("drop:0.25,corrupt:0.25", clip_norm=10.0)

    strat = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    sv, hv = run_rounds(
        init_sim_state(sim, strat, x0),
        make_round_fn(sim, strat, grad_fn, data, faults=faults), 4)
    sm, hm = run_rounds(
        init_sim_state(sim, strat, x0, placement=pl),
        make_round_fn(sim, strat, grad_fn, data, placement=pl,
                      faults=faults), 4)
    # the SCHEDULE is placement-independent (exact counts); values meet
    # the mesh's documented f32 reassociation tolerance
    for a, b in zip(hv, hm):
        assert a["screened"] == b["screened"], (a, b)
        assert a["dropped"] == b["dropped"], (a, b)
    for key in ("x", "clients", "pms"):
        for a, b in zip(jax.tree.leaves(sv[key]),
                        jax.tree.leaves(sm[key])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-6, err_msg=key)

    # fault_rate=0 on a real 4-way axis: bitwise the no-fault trace
    ref, _ = run_rounds(
        init_sim_state(sim, strat, x0, placement=pl),
        make_round_fn(sim, strat, grad_fn, data, placement=pl), 3)
    got, _ = run_rounds(
        init_sim_state(sim, strat, x0, placement=pl),
        make_round_fn(sim, strat, grad_fn, data, placement=pl,
                      faults=FaultConfig()), 3)
    for key in ("x", "clients", "pms"):
        for a, b in zip(jax.tree.leaves(ref[key]),
                        jax.tree.leaves(got[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)

    # one collective per screened round on the 4-device mesh, both
    # strategies
    def count(jx, names):
        n = 0
        for eqn in jx.eqns:
            if eqn.primitive.name in names:
                n += 1
            for v in eqn.params.values():
                if hasattr(v, "eqns"):
                    n += count(v, names)
                elif hasattr(v, "jaxpr"):
                    n += count(v.jaxpr, names)
        return n
    names = {"psum", "psum2", "all_gather", "all_to_all", "ppermute"}
    for s in (strat, Scaffold(eta=0.05)):
        rf = make_round_fn(sim, s, grad_fn, data, placement=pl,
                           faults=faults, donate=False)
        st = init_sim_state(sim, s, x0, placement=pl)
        assert count(jax.make_jaxpr(rf)(st).jaxpr, names) == 1, s.name

    print("FAULTS_4DEV_OK")
""")


def test_faults_4device_emulation():
    """4-way client axis: screened mesh rounds match screened vmap rounds
    (counts exact, state at 1e-6), fault_rate=0 stays bitwise, and the
    one-psum invariant holds for FedDeper and Scaffold."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         env=_SUBPROC_ENV, timeout=560)
    assert "FAULTS_4DEV_OK" in out.stdout, (out.stdout[-1000:],
                                            out.stderr[-3000:])


# --------------------------------------------------- ckpt config validation

def test_restore_rejects_mismatched_fault_config(tmp_path):
    """A checkpoint stamped with one compress/faults config refuses to
    resume a run requesting another (fail fast beats silently mixing
    EF/fault state); legacy checkpoints without the keys still restore."""
    import argparse
    from repro.checkpoint import save_checkpoint
    from repro.launch.train import _ckpt_tree, _restore_state

    state = {"x": {"w": jnp.ones(2)}, "clients": {}, "pms": {},
             "server": {}, "rng": jax.random.PRNGKey(0)}
    args = argparse.Namespace(ckpt_dir=str(tmp_path))
    save_checkpoint(str(tmp_path), 3, _ckpt_tree(state),
                    metadata={"compress": "none", "faults": "drop:0.2"})
    with pytest.raises(SystemExit, match="faults='drop:0.2'"):
        _restore_state(state, args,
                       expect={"compress": "none", "faults": "drop:0.5"})
    # matching config restores
    start, _ = _restore_state(state, args,
                              expect={"compress": "none",
                                      "faults": "drop:0.2"})
    assert start == 3
    # legacy checkpoint (no config keys): restored unchecked
    for f in tmp_path.iterdir():
        f.unlink()
    save_checkpoint(str(tmp_path), 5, _ckpt_tree(state))
    start, _ = _restore_state(state, args,
                              expect={"compress": "q8",
                                      "faults": "drop:0.9"})
    assert start == 5
