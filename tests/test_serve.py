"""Serving tier (repro.serve, DESIGN.md §13): flash-decode kernel,
slot-cache engine contracts, continuous batching, train->serve handoff,
weight sources, and the request simulator."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.configs import get_config
from repro.configs.specs import SpecError
from repro.kernels.flash_attention import flash_decode_bhsd, flash_decode_ref
from repro.models import init_model, transformer
from repro.models.attention import decode_attention
from repro.serve import (ServeEngine, SimConfig, init_slot_cache,
                         make_weight_source, read_slot, simulate)


def _cfg():
    return get_config("llama3.2-3b").reduced()


def _rand_qkv(rng, BK, G, D, Dv, L):
    q = jax.random.normal(rng[0], (BK, G, D), jnp.float32)
    k = jax.random.normal(rng[1], (BK, L, D), jnp.float32)
    v = jax.random.normal(rng[2], (BK, L, Dv), jnp.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# flash-decode kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BK,G,D,L,bkv,cap", [
    (3, 8, 64, 128, 128, None),   # single kv block
    (2, 4, 64, 96, 32, 30.0),     # multi-block + softcap
    (1, 8, 128, 48, 16, None),    # many short blocks, past-valid skip
])
def test_flash_decode_bitwise_vs_oracle(BK, G, D, L, bkv, cap):
    """Interpret-mode Pallas kernel is BITWISE identical to the jnp
    online-softmax oracle -- same op order, so the off-TPU oracle bypass
    in ops.flash_decode serves the exact kernel semantics."""
    rng = jax.random.split(jax.random.PRNGKey(7), 4)
    q, k, v = _rand_qkv(rng, BK, G, D, D, L)
    lens = jax.random.randint(rng[3], (BK,), 1, L + 1)
    out_k = flash_decode_bhsd(q, k, v, lens, cap=cap, block_kv=bkv,
                              interpret=True)
    out_r = flash_decode_ref(q, k, v, lens, cap=cap, block_kv=bkv)
    assert np.asarray(out_k).tobytes() == np.asarray(out_r).tobytes()


def test_flash_decode_matches_dense_attention():
    """ops.flash_decode == models.attention.decode_attention on the
    (B,1,H,Dq) x (B,L,K,D) decode layout, per-row lens, within f32
    tolerance (different reduction order)."""
    from repro.kernels.ops import flash_decode
    B, H, K, D, L = 3, 8, 4, 64, 50
    rng = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(rng[0], (B, 1, H, D), jnp.float32)
    kc = jax.random.normal(rng[1], (B, L, K, D), jnp.float32)
    vc = jax.random.normal(rng[2], (B, L, K, D), jnp.float32)
    lens = jnp.array([1, 17, 50], jnp.int32)
    got = flash_decode(q, kc, vc, lens=lens)
    want = decode_attention(q, kc, vc, valid_len=lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_softcap_and_mla_shape():
    """Softcap routes through the kernel path; the MLA single-kv-head
    layout (K=1 wide head) is supported."""
    from repro.kernels.ops import flash_decode
    B, L, D = 2, 24, 80
    rng = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(rng[0], (B, 1, 4, D), jnp.float32)
    kc = jax.random.normal(rng[1], (B, L, 1, D), jnp.float32)
    vc = jax.random.normal(rng[2], (B, L, 1, D), jnp.float32)
    got = flash_decode(q, kc, vc, lens=jnp.array([5, 24]), cap=50.0)
    want = decode_attention(q, kc, vc, valid_len=jnp.array([5, 24]),
                            cap=50.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# slot cache
# ---------------------------------------------------------------------------

def test_slot_cache_roundtrip():
    """write_slot/read_slot are inverses on both cache groups (prefix
    batch axis 0, pattern batch axis 1)."""
    from repro.serve.cache import write_slot
    cfg = _cfg()
    cache = init_slot_cache(cfg, 4, 16, jnp.float32)
    row = jax.tree.map(
        lambda t: jnp.arange(t.size, dtype=t.dtype).reshape(t.shape),
        read_slot(cache, 0))
    cache = write_slot(cache, row, 2)
    back = read_slot(cache, 2)
    for a, b in zip(jax.tree.leaves(row), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # other slots untouched (still zeros)
    other = read_slot(cache, 1)
    assert all(not np.asarray(l).any() for l in jax.tree.leaves(other))


# ---------------------------------------------------------------------------
# engine contracts
# ---------------------------------------------------------------------------

def _engine(params=None, **kw):
    cfg = _cfg()
    params = init_model(cfg, jax.random.PRNGKey(0)) \
        if params is None else params
    kw.setdefault("slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_tokens", 4)
    return cfg, params, ServeEngine(cfg, params, **kw)


def test_engine_block_compiles_once_and_donates():
    """The decode block compiles EXACTLY once per engine no matter how
    many blocks run (the launch/serve.py re-tracing hazard, pinned), and
    the cache buffer is donated through the block step."""
    cfg, params, eng = _engine()
    prompts = [np.arange(1, 4 + i) % cfg.vocab_size for i in range(4)]
    eng.generate(prompts, 9)  # admits + 2 blocks
    leaf_before = jax.tree.leaves(eng.cache)[0]
    eng.run_block()
    assert leaf_before.is_deleted(), "cache was copied, not donated"
    for i in range(4):
        eng.admit(i, prompts[i])
    eng.run_block()
    eng.run_block()
    assert eng.block_compile_count() == 1
    # admit compiles once per prompt-length bucket, not per prompt
    assert eng._prefill._cache_size() == 1  # all prompts in the 8-bucket


def test_engine_continuous_batching_matches_sequential():
    """Mixed-length prompts decoded together in slot batches emit
    EXACTLY the tokens each prompt gets decoded alone (scalar-pos
    reference loop) -- inactive-slot padding and per-row lens never leak
    across rows."""
    cfg, params, eng = _engine()
    rng = np.random.default_rng(5)
    lens = [5, 9, 12, 7]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    T = 9
    got = eng.generate(prompts, T)

    for b, prompt in enumerate(prompts):
        n = len(prompt)
        cache = init_slot_cache(cfg, 1, eng.max_len, jnp.float32)
        logits, cache = transformer.prefill(
            cfg, params, {"tokens": jnp.asarray(prompt)[None]}, cache,
            chunkwise=True, use_pallas=True,
            lens=jnp.array([n], jnp.int32))
        tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        want = [int(tok[0])]
        pos = n
        for _ in range(T - 1):
            logits, cache = transformer.decode_step(
                cfg, params, cache, tok.reshape(1, 1),
                jnp.array([pos], jnp.int32), chunkwise=True,
                use_pallas=True)
            tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            want.append(int(tok[0]))
            pos += 1
        np.testing.assert_array_equal(got[b], np.asarray(want))


def test_engine_slot_reuse_isolated():
    """Releasing a slot and admitting a new prompt into it must not
    disturb a still-active neighbour slot's stream."""
    cfg, params, eng = _engine(slots=2, block_tokens=3)
    rng = np.random.default_rng(9)
    pa = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    pc = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)

    # reference: pa alone for 2 blocks' worth of tokens
    solo = ServeEngine(cfg, params, slots=1, max_len=eng.max_len,
                       block_tokens=3)
    ref = solo.generate([pa], 7)[0]

    toks_a = [eng.admit(0, pa)]
    eng.admit(1, pb)
    toks_a.extend(int(t) for t in eng.run_block()[:, 0])
    eng.release(1)
    eng.admit(1, pc)  # churn slot 1 mid-stream
    toks_a.extend(int(t) for t in eng.run_block()[:, 0])
    np.testing.assert_array_equal(np.asarray(toks_a), ref)


# ---------------------------------------------------------------------------
# weight sources
# ---------------------------------------------------------------------------

def test_weight_source_specs():
    assert make_weight_source(None).name == "init:0"
    assert make_weight_source("init:7").name == "init:7"
    assert make_weight_source("q8").name == "q8:init:0"
    assert make_weight_source("fp8:init:3").name == "fp8:init:3"
    assert make_weight_source("ckpt:/tmp/x").name == "ckpt:/tmp/x"
    with pytest.raises(SpecError):
        make_weight_source("q8:fp8:init")  # nested quantization
    with pytest.raises(SpecError):
        make_weight_source("bogus:1")
    with pytest.raises(SpecError):
        make_weight_source("ckpt")  # ckpt needs a directory


def test_quantized_source_roundtrip():
    """q8 serving weights stay within one per-leaf quantization step of
    the dense source, and the resident footprint is ~1 byte/param."""
    cfg = _cfg()
    dense_src = make_weight_source("init:3")
    dense = dense_src.load(cfg)
    q = make_weight_source("q8:init:3").load(cfg)
    assert jax.tree.structure(q) == jax.tree.structure(dense)
    for d, qq in zip(jax.tree.leaves(dense), jax.tree.leaves(q)):
        step = float(jnp.max(jnp.abs(d))) / 127.0
        assert qq.dtype == d.dtype
        err = float(jnp.max(jnp.abs(qq.astype(jnp.float32) -
                                    d.astype(jnp.float32))))
        assert err <= step * 0.51 + 1e-8
    q8 = make_weight_source("q8")
    assert q8.resident_bytes(cfg) < dense_src.resident_bytes(cfg) / 3


# ---------------------------------------------------------------------------
# train -> serve handoff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("store", ["dense", "virtual:recon"])
def test_train_serve_handoff(tmp_path, store):
    """A launch/train.py checkpoint loads straight into the serving
    tier, and the engine's greedy decode from the restored weights is
    IDENTICAL to decoding from the same weights restored in-memory --
    for the dense client store AND the virtual layouts (member 0, the
    global model, is always dense)."""
    ckpt = str(tmp_path / store.replace(":", "_"))
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "llama3.2-3b", "--reduced", "--clients", "2", "--tau", "2",
            "--rounds", "2", "--batch", "2", "--seq", "32",
            "--ckpt-dir", ckpt, "--ckpt-every", "1"]
    if store != "dense":
        args += ["--store", store, "--placement", "vmap"]
    out = subprocess.run(args, capture_output=True, text=True,
                         env=_SUBPROC_ENV, cwd=".", timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]

    cfg = _cfg()
    src = make_weight_source(f"ckpt:{ckpt}")
    params = src.load(cfg)
    # trained weights, not init
    init = init_model(cfg, jax.random.PRNGKey(0))
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(init)))

    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 7)]
    served = ServeEngine(cfg, params, slots=2, max_len=32,
                         block_tokens=4).generate(prompts, 6)
    # in-memory restore through the checkpoint module directly
    from repro.checkpoint import latest_checkpoint, restore_subtree
    mem, _ = restore_subtree(latest_checkpoint(ckpt),
                             transformer.param_shapes(cfg), index=0)
    in_mem = ServeEngine(cfg, mem, slots=2, max_len=32,
                         block_tokens=4).generate(prompts, 6)
    np.testing.assert_array_equal(served, in_mem)


def test_ckpt_source_missing_dir(tmp_path):
    with pytest.raises(SystemExit):
        make_weight_source(f"ckpt:{tmp_path}/nope").load(_cfg())


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

def test_simulator_deterministic_and_reuses_slots():
    """time_unit > 0 makes the trace fully deterministic; more requests
    than slots all complete via slot reuse with the full token count."""
    cfg, params, eng = _engine(slots=2, block_tokens=4)
    sim = SimConfig(requests=5, prompt_lens=(3, 5, 8), gen_tokens=6,
                    delay=0.4, delay_dist="lognormal", seed=1,
                    time_unit=0.01)
    m1 = simulate(eng, sim)
    cfg, params, eng2 = _engine(params=params, slots=2, block_tokens=4)
    m2 = simulate(eng2, sim)
    assert m1 == m2
    assert m1["requests"] == 5
    assert all(r["generated"] == 6 for r in m1["per_request"])
    assert m1["generated"] == 5 * 6
    assert m1["p99_ms"] >= m1["p50_ms"] > 0
    # later arrivals exist (delay > 0) yet every request finished
    assert m1["per_request"][-1]["arrival_s"] > 0
    assert eng.block_compile_count() == 1


def test_serve_cli_entrypoint():
    """launch/serve.py end to end: batch mode JSON with the compile-once
    receipt; --simulate mode runs the request simulator."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "llama3.2-3b", "--reduced", "--slots", "2", "--max-len", "32",
         "--prompt-len", "4", "--gen-tokens", "8", "--block-tokens", "4"],
        capture_output=True, text=True, env=_SUBPROC_ENV, cwd=".",
        timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["mode"] == "batch"
    assert res["generated"] == 2 * 8
    assert res["block_compiles"] == 1
    assert res["tokens_per_s"] > 0
