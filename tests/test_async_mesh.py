"""Async-on-mesh aggregation: staleness discounts composed with the mesh
psum.  The staleness-weighted buffered aggregate must lower to exactly ONE
cross-client collective (``engine._psum_mean_fn``'s weighted path), match
the host-side ``tree_weighted_mean`` reference (bitwise on a 1-device
mesh, documented f32 tolerance on 4 devices), keep the zero-weight-sum
guard, and pad non-dividing buffers/dispatches with massless lanes
(DESIGN.md §9)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.configs.paper_models import MLP_MNIST
from repro.core import (AsyncSimConfig, FedAvg, FedDeper, MeshPlacement,
                        Scaffold, init_async_state, make_async_round_fn,
                        pad_cohort, run_rounds)
from repro.data import make_federated_classification
from repro.launch.mesh import make_client_mesh
from repro.models import classifier_loss, init_classifier

CFG = MLP_MNIST


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(7))


@pytest.fixture(scope="module")
def data():
    ds = make_federated_classification(n_clients=8, per_client=64,
                                       split="shards", seed=1)
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


def _rand_uploads(strategy, x, m, seed):
    """An (m, ...) upload stack shaped like ``strategy.upload_template``
    (Scaffold's doubles to {dv, dc})."""
    tmpl = strategy.upload_template(x)
    leaves, treedef = jax.tree.flatten(tmpl)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(treedef, [
        jax.random.normal(k, (m,) + tuple(l.shape)).astype(l.dtype)
        for k, l in zip(keys, leaves)])


COLLECTIVES = {"psum", "psum2", "all_gather", "all_to_all", "ppermute",
               "pmax", "pmin"}


def count_collectives(jaxpr) -> int:
    """Recursively count collective primitives in a (closed) jaxpr
    (same recursion as test_engine_placement: shard_map params hold raw
    ``Jaxpr`` objects, hence the ``eqns`` check first)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVES:
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "eqns"):
                n += count_collectives(v)
            elif hasattr(v, "jaxpr"):
                n += count_collectives(v.jaxpr)
    return n


STRATS = [FedDeper(eta=0.05, rho=0.03, lam=0.5), FedAvg(eta=0.05),
          Scaffold(eta=0.05)]
W8 = jnp.asarray([1.0, 0.25, 0.5, 1.0, 0.125, 0.7, 0.3, 1.0])


@pytest.mark.parametrize("strategy", STRATS,
                         ids=[s.name for s in STRATS])
def test_weighted_aggregate_buffer_bitwise_on_1device_mesh(strategy, x0):
    """On a 1-device mesh the psum-lowered weighted mean runs the exact
    ops of ``tree_weighted_mean`` (full-vector normalization, full-width
    slice, tensordot, size-1 psum), so the mesh aggregate is the host
    aggregate bitwise -- including Scaffold's weight-normalized c-update."""
    pl = MeshPlacement(make_client_mesh())
    ups = _rand_uploads(strategy, x0, 8, seed=3)
    xh, sh, _ = strategy.aggregate(x0, strategy.server_init(x0), ups,
                                   8 / 16, weights=W8)
    xm, sm, _ = pl.aggregate_buffer(strategy, x0, strategy.server_init(x0),
                                    pl.place_uploads(ups), 8 / 16,
                                    weights=W8)
    for a, b in zip(jax.tree.leaves((xh, sh)), jax.tree.leaves((xm, sm))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=strategy.name)


@pytest.mark.parametrize("strategy", [STRATS[0], STRATS[2]],
                         ids=["feddeper", "scaffold"])
def test_zero_weight_sum_guard_on_mesh(strategy, x0):
    """All-zero weights (every upload fully discounted) fall back to the
    uniform mean on the mesh exactly like ``tree_weighted_mean``'s guard
    -- no division by zero, and bitwise the same fallback as the host."""
    pl = MeshPlacement(make_client_mesh())
    ups = _rand_uploads(strategy, x0, 8, seed=4)
    w0 = jnp.zeros(8)
    xh, sh, _ = strategy.aggregate(x0, strategy.server_init(x0), ups,
                                   8 / 16, weights=w0)
    xm, sm, _ = pl.aggregate_buffer(strategy, x0, strategy.server_init(x0),
                                    pl.place_uploads(ups), 8 / 16,
                                    weights=w0)
    for a, b in zip(jax.tree.leaves((xh, sh)), jax.tree.leaves((xm, sm))):
        assert np.all(np.isfinite(np.asarray(b)))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=strategy.name)
    # ... and the fallback really is the uniform mean (not zero)
    xu, _, _ = strategy.aggregate(x0, strategy.server_init(x0), ups, 8 / 16)
    for a, b in zip(jax.tree.leaves(xu), jax.tree.leaves(xm)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("strategy", [STRATS[0], STRATS[2]],
                         ids=["feddeper", "scaffold"])
def test_weighted_aggregate_has_exactly_one_collective(strategy, x0):
    """The weighted upload-sum and the weight normalization ride the SAME
    psum the uniform path uses: one collective per aggregation, for the
    single-upload strategies AND Scaffold's {dv, dc} double payload."""
    pl = MeshPlacement(make_client_mesh())
    ups = _rand_uploads(strategy, x0, 8, seed=5)
    jx = jax.make_jaxpr(
        lambda x, s, u, w: pl.aggregate_buffer(strategy, x, s, u, 0.5,
                                               weights=w))(
        x0, strategy.server_init(x0), ups, W8)
    assert count_collectives(jx.jaxpr) == 1


def test_pad_cohort_modes():
    tree = {"a": jnp.arange(12.0).reshape(6, 2), "b": jnp.arange(6.0)}
    padded, n_real = pad_cohort(tree, 4, mode="edge")
    assert n_real == 6
    assert padded["a"].shape == (8, 2) and padded["b"].shape == (8,)
    np.testing.assert_array_equal(np.asarray(padded["a"][6:]),
                                  np.broadcast_to(np.asarray(tree["a"][-1]),
                                                  (2, 2)))
    zeroed, _ = pad_cohort(tree, 4, mode="zero")
    np.testing.assert_array_equal(np.asarray(zeroed["b"][6:]), np.zeros(2))
    np.testing.assert_array_equal(np.asarray(zeroed["a"][:6]),
                                  np.asarray(tree["a"]))
    same, n = pad_cohort(tree, 3, mode="edge")  # 6 % 3 == 0: identity
    assert n == 6 and same["a"] is tree["a"]
    empty, n = pad_cohort({}, 4)
    assert n == 0 and empty == {}


def test_async_mesh_weighted_straggler_matches_vmap_1device(data, x0):
    """Full async regime with real staleness discounts (alpha>0, lognormal
    stragglers) on a 1-device mesh: host scheduling is shared and the
    dispatch shard_map wraps the same vmap body, so the mesh trajectory
    tracks the vmap trajectory at f32 tolerance.  (Not bitwise: XLA's jit
    of the HOST ``agg_weighted`` reassociates the odd-m tensordot away
    from its own eager math by ~1e-9 per round -- the mesh aggregate
    reproduces the eager ``tree_weighted_mean`` exactly, which is the
    bitwise pin the aggregate-level tests above hold.)"""
    acfg = AsyncSimConfig(n_clients=8, m_concurrent=4, buffer_size=3,
                          tau=2, batch_size=8, alpha=0.5, delay=4.0,
                          delay_dist="lognormal", seed=5)
    strat = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    pl = MeshPlacement(make_client_mesh())
    sv, hv = run_rounds(init_async_state(acfg, strat, x0),
                        make_async_round_fn(acfg, strat, grad_fn, data), 4)
    sm, hm = run_rounds(
        init_async_state(acfg, strat, x0, placement=pl),
        make_async_round_fn(acfg, strat, grad_fn, data, placement=pl), 4)
    for key in ("x", "clients", "pms"):
        for a, b in zip(jax.tree.leaves(sv[key]),
                        jax.tree.leaves(sm[key])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-6, err_msg=key)
    for rv, rm in zip(hv, hm):
        assert set(rv) == set(rm)
        assert rv["version"] == rm["version"]
        assert rv["sim_time"] == rm["sim_time"]
        for k in rv:
            np.testing.assert_allclose(rv[k], rm[k], rtol=1e-5, atol=1e-5,
                                       err_msg=k)


# ------------------------------------------------- 4-device CPU emulation

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.paper_models import MLP_MNIST
    from repro.core import (AsyncSimConfig, FedAvg, FedDeper, Scaffold,
                            MeshPlacement, init_async_state,
                            make_async_round_fn, pad_cohort, run_rounds)
    from repro.data import make_federated_classification
    from repro.launch.mesh import make_client_mesh
    from repro.models import classifier_loss, init_classifier

    assert jax.local_device_count() == 4
    pl = MeshPlacement(make_client_mesh())
    assert pl.axis_size == 4
    x0 = init_classifier(MLP_MNIST, jax.random.PRNGKey(7))

    def rand_uploads(strategy, m, seed):
        tmpl = strategy.upload_template(x0)
        leaves, treedef = jax.tree.flatten(tmpl)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
        return jax.tree.unflatten(treedef, [
            jax.random.normal(k, (m,) + tuple(l.shape)).astype(l.dtype)
            for k, l in zip(keys, leaves)])

    # 1) cohort_map pads non-dividing cohorts (6 lanes on a 4-way axis)
    #    with masked edge lanes and slices them back: identity to callers
    a6 = jnp.arange(18.0).reshape(6, 3)
    out = pl.cohort_map(lambda a: a * 2.0, in_axes=(0,))(a6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a6) * 2.0)

    # 2) weighted aggregate_buffer == host aggregate (f32 psum tolerance)
    w8 = jnp.asarray([1.0, 0.25, 0.5, 1.0, 0.125, 0.7, 0.3, 1.0])
    for strat in (FedDeper(eta=0.05, rho=0.03, lam=0.5), FedAvg(eta=0.05),
                  Scaffold(eta=0.05)):
        ups = rand_uploads(strat, 8, seed=3)
        xh, sh, _ = strat.aggregate(x0, strat.server_init(x0), ups,
                                    8 / 16, weights=w8)
        xm, sm, _ = pl.aggregate_buffer(strat, x0, strat.server_init(x0),
                                        pl.place_uploads(ups), 8 / 16,
                                        weights=w8)
        for a, b in zip(jax.tree.leaves((xh, sh)),
                        jax.tree.leaves((xm, sm))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-6,
                                       err_msg=strat.name)

    # 2b) massless padding: 6 real uploads zero-padded to 8 with zero
    #     weights == the unpadded host aggregate (Scaffold's weight-
    #     normalized p_eff makes the c-update padding-invariant: the host
    #     gets p = 6/n, the mesh p = 8/n, both resolve to sum(w)/n)
    w6 = jnp.asarray([1.0, 0.5, 0.25, 0.8, 0.4, 1.0])
    for strat in (FedAvg(eta=0.05), Scaffold(eta=0.05)):
        ups6 = rand_uploads(strat, 6, seed=4)
        xh, sh, _ = strat.aggregate(x0, strat.server_init(x0), ups6,
                                    6 / 16, weights=w6)
        ups8, m_real = pad_cohort(ups6, 4, mode="zero")
        assert m_real == 6 and jax.tree.leaves(ups8)[0].shape[0] == 8
        w = jnp.concatenate([w6, jnp.zeros(2)])
        xm, sm, _ = pl.aggregate_buffer(strat, x0, strat.server_init(x0),
                                        pl.place_uploads(ups8), 8 / 16,
                                        weights=w)
        for a, b in zip(jax.tree.leaves((xh, sh)),
                        jax.tree.leaves((xm, sm))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-6,
                                       err_msg="padded/" + strat.name)

    # 3) exactly ONE cross-client collective per weighted aggregation
    names = {"psum", "psum2", "all_gather", "all_to_all", "ppermute",
             "pmax", "pmin"}
    def count(jx):
        n = 0
        for eqn in jx.eqns:
            if eqn.primitive.name in names:
                n += 1
            for v in eqn.params.values():
                if hasattr(v, "eqns"):
                    n += count(v)
                elif hasattr(v, "jaxpr"):
                    n += count(v.jaxpr)
        return n
    for strat in (FedDeper(eta=0.05, rho=0.03, lam=0.5),
                  Scaffold(eta=0.05)):
        ups = rand_uploads(strat, 8, seed=5)
        jx = jax.make_jaxpr(
            lambda x, s, u, w: pl.aggregate_buffer(strat, x, s, u, 0.5,
                                                   weights=w))(
            x0, strat.server_init(x0), ups, w8)
        assert count(jx.jaxpr) == 1, (strat.name, count(jx.jaxpr))

    # 4) end-to-end: heavy-tailed stragglers, alpha=0.5, buffer_size=3
    #    (never divides the 4-way axis -> every aggregation pads) -- the
    #    mesh trajectory matches the vmap trajectory at f32 tolerance
    ds = make_federated_classification(n_clients=8, per_client=64,
                                       split="shards", seed=1)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(
            lambda p, b: classifier_loss(MLP_MNIST, p, b),
            has_aux=True)(p, mb)
        return l, g
    acfg = AsyncSimConfig(n_clients=8, m_concurrent=4, buffer_size=3,
                          tau=2, batch_size=8, alpha=0.5, delay=4.0,
                          delay_dist="lognormal", seed=5)
    strat = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    sv, hv = run_rounds(init_async_state(acfg, strat, x0),
                        make_async_round_fn(acfg, strat, grad_fn, data), 4)
    sm, hm = run_rounds(
        init_async_state(acfg, strat, x0, placement=pl),
        make_async_round_fn(acfg, strat, grad_fn, data, placement=pl), 4)
    for key in ("x", "clients", "pms"):
        for a, b in zip(jax.tree.leaves(sv[key]),
                        jax.tree.leaves(sm[key])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-6, err_msg=key)
    for rv, rm in zip(hv, hm):
        assert rv["version"] == rm["version"]
        assert rv["sim_time"] == rm["sim_time"]
        np.testing.assert_allclose(rv["staleness_mean"],
                                   rm["staleness_mean"], rtol=0, atol=0)

    print("ASYNC_MESH_4DEV_OK")
""")


def test_async_mesh_4device_emulation():
    """4-way client axis: cohort_map padding identity, weighted aggregate
    vs host reference (plain and zero-padded), one collective per
    weighted aggregation, and the straggler async regime mesh-vs-vmap."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         env=_SUBPROC_ENV, timeout=560)
    assert "ASYNC_MESH_4DEV_OK" in out.stdout, (out.stdout[-1000:],
                                                out.stderr[-3000:])
