"""Byzantine-robust aggregation: reducers, stealth attacks, screening
composition (ISSUE 9).

Contract under test: ``robust=none`` is normalized out of the trace --
the round program is BITWISE the plain engine's on both placements (one
psum, jaxpr-counted) for FedDeper AND Scaffold, across the host loop,
scan blocks, and EF compression.  The gather modes (trimmed / median /
krum) cost exactly ONE all_gather + ONE psum on the mesh; bucket mode
rides the round's single psum.  Both placements run the same reducer
math over the same full stack, so mesh == vmap bitwise for every mode.
Stealth attacks (alie / collude / ipflip) are finite-valued -- they pass
PR 7's screening by construction -- and the acceptance run pins that
Krum recovers what the plain mean loses under clip-riding collusion.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.comm import make_compressor
from repro.configs.paper_models import MLP_MNIST
from repro.core import (FedDeper, MeshPlacement, Scaffold, SimConfig,
                        RobustConfig, init_sim_state, make_block_fn,
                        make_global_eval, make_layout, make_robust,
                        make_round_fn, run_blocks, run_rounds,
                        state_is_finite)
from repro.core.store import make_virtual_round_fn
from repro.data import make_federated_classification
from repro.faults import (FaultConfig, STEALTH_MODES, attack_round_key,
                          corrupt_payload, make_faults, needs_attack_key)
from repro.launch.mesh import make_client_mesh
from repro.models import classifier_loss, init_classifier
from repro.robust import (ROBUST_MODES, bucket_finish, bucket_partials,
                          krum_weights, masked_mean, pack_cohort,
                          robust_reduce, trim_count, trimmed_reduce)

CFG = MLP_MNIST

DEPER = FedDeper(eta=0.05, rho=0.03, lam=0.5)


def apply_loss(p, b):
    return classifier_loss(CFG, p, b)


def grad_fn(p, mb):
    (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
    return l, g


@pytest.fixture(scope="module")
def ds():
    return make_federated_classification(n_clients=6, per_client=64,
                                         split="shards", seed=2)


@pytest.fixture(scope="module")
def data(ds):
    return {k: jnp.asarray(v) for k, v in ds.train.items()}


@pytest.fixture(scope="module")
def x0():
    return init_classifier(CFG, jax.random.PRNGKey(11))


SIM = SimConfig(n_clients=6, m_sampled=4, tau=2, batch_size=16, seed=5)

# every reducer mode at a parameterization feasible for m=4
MODE_SPECS = ("trimmed:0.25", "median", "krum:0.25", "bucket:4")

COLLECTIVES = {"psum", "psum2", "all_gather", "all_to_all", "ppermute",
               "pmax", "pmin"}


def count_collectives(jaxpr, names=COLLECTIVES):
    counts = {}
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names:
            counts[eqn.primitive.name] = \
                counts.get(eqn.primitive.name, 0) + 1
        for v in eqn.params.values():
            sub = None
            if hasattr(v, "eqns"):
                sub = v
            elif hasattr(v, "jaxpr"):
                sub = v.jaxpr
            if sub is not None:
                for k, n in count_collectives(sub, names).items():
                    counts[k] = counts.get(k, 0) + n
    return counts


def _leaves_equal(a, b, keys=("x", "clients", "pms"), atol=0.0, msg=""):
    for key in keys:
        for la, lb in zip(jax.tree.leaves(a[key]), jax.tree.leaves(b[key])):
            if atol:
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=0, atol=atol,
                                           err_msg=f"{msg}{key}")
            else:
                np.testing.assert_array_equal(np.asarray(la),
                                              np.asarray(lb),
                                              err_msg=f"{msg}{key}")


# ----------------------------------------------------------- config/parsing

def test_make_robust_parsing_roundtrip():
    for spec in ("median", "trimmed:0.25", "trimmed:0.1", "krum:0.2",
                 "bucket:4", "bucket:3,inner:trimmed",
                 "bucket:4,inner:trimmed,frac:0.3"):
        cfg = make_robust(spec)
        assert make_robust(cfg.spec).spec == cfg.spec, spec
    assert make_robust(None) is None
    assert make_robust("none") is None
    assert make_robust("") is None
    cfg = make_robust("trimmed:0.25")
    assert make_robust(cfg) is cfg  # RobustConfig passes through
    assert make_robust("trimmed").frac == 0.25  # default frac
    assert make_robust("krum:0.3").gathers
    assert not make_robust("bucket:4").gathers


def test_make_robust_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown mode"):
        make_robust("garbage")
    # the error enumerates every mode (the --robust help contract)
    with pytest.raises(ValueError, match="|".join(ROBUST_MODES)):
        make_robust("garbage")
    with pytest.raises(ValueError, match="takes no parameter"):
        make_robust("median:0.3")
    with pytest.raises(ValueError, match="unknown key"):
        make_robust("trimmed:0.2,inner:median")
    with pytest.raises(ValueError, match="frac must be in"):
        make_robust("trimmed:0.5")
    with pytest.raises(ValueError, match="buckets must be"):
        make_robust("bucket:1")
    with pytest.raises(ValueError, match="inner mode"):
        make_robust("bucket:4,inner:krum")


def test_check_cohort_feasibility():
    make_robust("trimmed:0.25").check_cohort(4)  # f=1, band of 2: fine
    with pytest.raises(ValueError, match="trims"):
        make_robust("trimmed:0.4").check_cohort(4)  # f=2, empty band
    make_robust("krum:0.25").check_cohort(2)  # keep >= 1 always holds
    with pytest.raises(ValueError, match="exceeds the cohort"):
        make_robust("bucket:8").check_cohort(4)
    assert trim_count(0.25, 4) == 1 and trim_count(0.2, 10) == 2


# ------------------------------------------------------------ reducer units

def _stack(honest=3.0, outlier=100.0, m=5, d=7, seed=0):
    """(m, d) stack: m-1 honest lanes near ``honest``, lane 0 a planted
    outlier at ``outlier``."""
    v = honest + 0.1 * jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    return {"a": v.at[0].set(outlier), "b": v[:, :3].at[0].set(-outlier)}


@pytest.mark.parametrize("spec", MODE_SPECS)
def test_reducers_reject_planted_outlier(spec):
    """One lane at +-100 against honest lanes near 3: every robust mode
    lands near the honest value; the plain mean is dragged ~20x off."""
    tree = _stack()
    w = jnp.ones(5)
    cfg = make_robust(spec if "bucket" not in spec else "bucket:5")
    out = robust_reduce(cfg, tree, w)
    for leaf in jax.tree.leaves(out):
        assert np.all(np.abs(np.abs(np.asarray(leaf)) - 3.0) < 1.0), spec
    mean = np.asarray(tree["a"]).mean(axis=0)
    assert np.all(np.abs(mean) > 20.0)  # what the outlier does unrobust


def test_trimmed_reduce_drops_exact_tails():
    """Deterministic band check: values 0..4 per coordinate, f=1 -> mean
    of {1, 2, 3} = 2 exactly."""
    t = {"a": jnp.arange(5.0)[:, None] * jnp.ones((5, 3))}
    out = trimmed_reduce(make_robust("trimmed:0.2"), t, jnp.ones(5))
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0, rtol=1e-6)
    med = trimmed_reduce(make_robust("median"), t, jnp.ones(5))
    np.testing.assert_allclose(np.asarray(med["a"]), 2.0, rtol=1e-6)


def test_reducers_ignore_zero_weight_lanes():
    """A screened lane (w=0, zero values -- faults.screen_upload's
    invariant) is massless: krum never keeps it, trimmed's band mean
    excludes it, and masked_mean matches the honest-only mean."""
    v = jnp.stack([jnp.full((4,), 2.0), jnp.full((4,), 4.0),
                   jnp.zeros(4)])  # lane 2 screened
    tree, w = {"a": v}, jnp.array([1.0, 1.0, 0.0])
    kw = krum_weights(RobustConfig("krum", frac=0.3), tree, w)
    assert float(kw[2]) == 0.0
    out = masked_mean(tree, kw)
    np.testing.assert_allclose(np.asarray(out["a"]), 3.0, rtol=1e-6)
    # trimmed with f=0: pure weighted mean, the zero lane carries none
    out = trimmed_reduce(RobustConfig("trimmed", frac=0.0), tree, w)
    np.testing.assert_allclose(np.asarray(out["a"]), 3.0, rtol=1e-6)


def test_masked_mean_zero_mass_falls_back_to_uniform():
    """All-screened cohort: zero total mass degrades to the uniform mean
    of the (all-zero) values -- the psum path's zero-delta behavior."""
    tree = {"a": jnp.zeros((3, 2))}
    out = masked_mean(tree, jnp.zeros(3))
    np.testing.assert_array_equal(np.asarray(out["a"]), 0.0)


def test_bucket_partials_linear_and_finish_matches_full():
    """The mesh contract behind bucket mode: partial sums computed on
    two disjoint lane shards (with the correct global lane0 offsets) ADD
    to the single-shard partials -- they are linear, so the psum can
    carry them -- and bucket_finish over the summed partials equals the
    single-device robust_reduce."""
    cfg = make_robust("bucket:3")
    tree = _stack(m=6, d=4)
    w = jnp.ones(6).at[4].set(0.0)
    ref_sums, ref_wsum = bucket_partials(cfg, tree, w, 0)
    lo = jax.tree.map(lambda t: t[:3], tree)
    hi = jax.tree.map(lambda t: t[3:], tree)
    s0, w0 = bucket_partials(cfg, lo, w[:3], 0)
    s1, w1 = bucket_partials(cfg, hi, w[3:], 3)
    summed = jax.tree.map(jnp.add, s0, s1)
    _leaves_equal({"x": ref_sums}, {"x": summed}, keys=("x",),
                  atol=1e-6, msg="partials:")
    np.testing.assert_allclose(np.asarray(w0 + w1), np.asarray(ref_wsum),
                               rtol=1e-6)
    full = robust_reduce(cfg, tree, w)
    fin = bucket_finish(cfg, summed, w0 + w1)
    _leaves_equal({"x": full}, {"x": fin}, keys=("x",), atol=1e-6,
                  msg="finish:")


def test_pack_cohort_roundtrips_tree_and_weights():
    """The one-all_gather packing is lossless: unpack(pack) returns the
    f32 tree and weights bitwise (gather order == lane order is what
    makes the mesh reduce bitwise-equal to vmap's)."""
    tree = {"a": jnp.ones((4, 2, 3)) * jnp.arange(4.0)[:, None, None],
            "b": {"c": jnp.arange(8.0).reshape(4, 2)}}
    w = jnp.array([1.0, 0.5, 0.0, 1.0])
    buf, unpack = pack_cohort(tree, w)
    assert buf.ndim == 2 and buf.shape[0] == 4
    got_tree, got_w = unpack(buf)
    _leaves_equal({"x": tree}, {"x": got_tree}, keys=("x",), msg="pack:")
    np.testing.assert_array_equal(np.asarray(got_w), np.asarray(w))


# ------------------------------------------------------------ stealth units

def _upload():
    return {"a": jnp.arange(1.0, 5.0), "b": jnp.array([[2.0, -3.0]])}


def test_collude_negates_and_rides_clip_boundary():
    """collude without a clip negates the upload; with clip_norm > 0 it
    rescales the negated upload to EXACTLY the clip boundary -- the
    largest payload screening will pass at full weight."""
    up, on = _upload(), jnp.asarray(True)
    key = jax.random.PRNGKey(0)
    akey = attack_round_key(jax.random.PRNGKey(3))
    cfg = FaultConfig(corrupt=1.0, corrupt_mode="collude")
    out = corrupt_payload(cfg, up, on, key, akey=akey)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(up)):
        np.testing.assert_allclose(np.asarray(a), -np.asarray(b),
                                   rtol=1e-6)
    cfg = FaultConfig(corrupt=1.0, corrupt_mode="collude", clip_norm=7.0)
    out = corrupt_payload(cfg, up, on, key, akey=akey)
    norm = np.sqrt(sum(float(jnp.sum(jnp.square(t)))
                       for t in jax.tree.leaves(out)))
    np.testing.assert_allclose(norm, 7.0, rtol=1e-5)
    # direction is exactly -upload (colinear, negative)
    dot = sum(float(jnp.sum(a * b)) for a, b in
              zip(jax.tree.leaves(out), jax.tree.leaves(up)))
    assert dot < 0


def test_ipflip_scales_by_attack_z():
    cfg = FaultConfig(corrupt=1.0, corrupt_mode="ipflip", attack_z=2.5)
    up, on = _upload(), jnp.asarray(True)
    out = corrupt_payload(cfg, up, on, jax.random.PRNGKey(0),
                          akey=attack_round_key(jax.random.PRNGKey(3)))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(up)):
        np.testing.assert_allclose(np.asarray(a), -2.5 * np.asarray(b),
                                   rtol=1e-6)


def test_alie_perturbs_finite_with_shared_direction():
    """alie: finite small-sigma perturbation whose per-coordinate SIGN
    pattern comes from the shared attack key -- two colluding lanes with
    the same akey perturb in the same direction (that coordination is
    what lets them shift a plain mean without tripping any screen)."""
    akey = attack_round_key(jax.random.PRNGKey(7))
    on = jnp.asarray(False), jnp.asarray(True)
    up1 = {"a": jnp.arange(8.0), "b": jnp.ones((2, 3))}
    up2 = {"a": jnp.arange(8.0) * 2.0 + 1.0, "b": -jnp.ones((2, 3))}
    cfg = FaultConfig(corrupt=1.0, corrupt_mode="alie", attack_z=1.5)
    o1 = corrupt_payload(cfg, up1, on[1], jax.random.PRNGKey(0), akey=akey)
    o2 = corrupt_payload(cfg, up2, on[1], jax.random.PRNGKey(1), akey=akey)
    d1 = np.sign(np.asarray(o1["a"]) - np.asarray(up1["a"]))
    d2 = np.sign(np.asarray(o2["a"]) - np.asarray(up2["a"]))
    assert np.all(np.isfinite(np.asarray(o1["a"])))
    np.testing.assert_array_equal(d1, d2)  # shared attack direction
    assert set(np.unique(d1)) == {-1.0, 1.0}  # genuinely two-sided
    # the off lane is untouched regardless of the attack key
    off = corrupt_payload(cfg, up1, on[0], jax.random.PRNGKey(0),
                          akey=akey)
    _leaves_equal({"x": off}, {"x": up1}, keys=("x",), msg="off:")


def test_stealth_attacks_pass_screening():
    """The point of stealth: every stealth payload is finite and (with
    collude riding the boundary) at or under the clip norm, so screening
    keeps it at full weight -- only the robust reducer can reject it."""
    from repro.faults import screen_upload
    up, on = _upload(), jnp.asarray(True)
    akey = attack_round_key(jax.random.PRNGKey(1))
    for mode in STEALTH_MODES:
        cfg = FaultConfig(corrupt=1.0, corrupt_mode=mode, clip_norm=50.0)
        assert needs_attack_key(cfg)
        out = corrupt_payload(cfg, up, on, jax.random.PRNGKey(0),
                              akey=akey)
        _, w, fm = screen_upload(cfg, out, jnp.asarray(False))
        assert float(w) == 1.0, mode
        assert float(fm["screened"]) == 0.0, mode


# ------------------------------------------- robust=none bitwise (satellite)

@pytest.mark.parametrize("strategy", [DEPER, Scaffold(eta=0.05)],
                         ids=["feddeper", "scaffold"])
@pytest.mark.parametrize("compress", [None, "topk:0.25"],
                         ids=["dense", "ef"])
def test_robust_none_bitwise_both_placements(strategy, compress, data, x0):
    """robust='none' is normalized out of the trace: host-loop AND K=3
    scan-block trajectories are bitwise the plain engine's, on vmap and
    on the mesh placement, dense and through the TopK(EF) compressor,
    for FedDeper and Scaffold."""
    comp = make_compressor(compress) if compress else None
    for pl in (None, MeshPlacement(make_client_mesh())):
        tag = f"{strategy.name}:{pl and 'mesh' or 'vmap'}:"
        ref, _ = run_rounds(
            init_sim_state(SIM, strategy, x0, placement=pl,
                           compressor=comp),
            make_round_fn(SIM, strategy, grad_fn, data, placement=pl,
                          compressor=comp), 3)
        got, _ = run_rounds(
            init_sim_state(SIM, strategy, x0, placement=pl,
                           compressor=comp),
            make_round_fn(SIM, strategy, grad_fn, data, placement=pl,
                          compressor=comp, robust="none"), 3)
        _leaves_equal(ref, got, msg=tag)
        gotb, _ = run_blocks(
            init_sim_state(SIM, strategy, x0, placement=pl,
                           compressor=comp),
            lambda size: make_block_fn(SIM, strategy, grad_fn, data,
                                       block_size=size, placement=pl,
                                       compressor=comp, robust="none"),
            3, 3)
        _leaves_equal(ref, gotb, msg=f"{tag}K=3:")


@pytest.mark.parametrize("strategy", [DEPER, Scaffold(eta=0.05)],
                         ids=["feddeper", "scaffold"])
def test_robust_none_mesh_program_identical(strategy, data, x0):
    """Stronger than trajectory equality: the robust='none' mesh round
    PROGRAM is the plain round's -- same jaxpr, one collective."""
    pl = MeshPlacement(make_client_mesh())
    state = init_sim_state(SIM, strategy, x0, placement=pl)
    ref = make_round_fn(SIM, strategy, grad_fn, data, placement=pl,
                        donate=False)
    got = make_round_fn(SIM, strategy, grad_fn, data, placement=pl,
                        donate=False, robust="none")
    jref = jax.make_jaxpr(ref)(state)
    jgot = jax.make_jaxpr(got)(state)
    # jaxpr text embeds callable object addresses (pjit/custom_jvp
    # params); normalize them -- the PROGRAM must match, not the ids
    import re
    norm = lambda j: re.sub(r"0x[0-9a-f]+", "0x", str(j))  # noqa: E731
    assert norm(jref) == norm(jgot)
    assert sum(count_collectives(jgot.jaxpr).values()) == 1


# --------------------------------------------------- mesh collective budget

@pytest.mark.parametrize("strategy", [DEPER, Scaffold(eta=0.05)],
                         ids=["feddeper", "scaffold"])
@pytest.mark.parametrize("spec", MODE_SPECS)
def test_mesh_collective_budget_per_mode(strategy, spec, data, x0):
    """The declared budget, jaxpr-counted: gather modes cost exactly one
    all_gather + one psum; bucket rides the round's single psum (its
    partials join the existing multi-operand collective)."""
    pl = MeshPlacement(make_client_mesh())
    faults = make_faults("collude:0.25,clip:5.0")
    rf = make_round_fn(SIM, strategy, grad_fn, data, placement=pl,
                       faults=faults, robust=spec, donate=False)
    state = init_sim_state(SIM, strategy, x0, placement=pl)
    counts = count_collectives(jax.make_jaxpr(rf)(state).jaxpr)
    cfg = make_robust(spec)
    gathers = counts.pop("all_gather", 0)
    psums = sum(counts.values())
    if cfg.gathers:
        assert (gathers, psums) == (1, 1), (spec, strategy.name, counts)
    else:
        assert (gathers, psums) == (0, 1), (spec, strategy.name, counts)


@pytest.mark.parametrize("spec", MODE_SPECS)
def test_mesh_matches_vmap_bitwise_per_mode(spec, data, x0):
    """Both placements run the identical reducer over the identical full
    stack (pack/gather/unpack preserves lane order and values exactly),
    so the trajectories agree BITWISE -- stronger than the 1e-6 the
    plain weighted mean manages, because the robust reduce does not
    reassociate across shards."""
    faults = make_faults("collude:0.25,clip:5.0")
    pl = MeshPlacement(make_client_mesh())
    sv, hv = run_rounds(
        init_sim_state(SIM, DEPER, x0),
        make_round_fn(SIM, DEPER, grad_fn, data, faults=faults,
                      robust=spec), 3)
    sm, hm = run_rounds(
        init_sim_state(SIM, DEPER, x0, placement=pl),
        make_round_fn(SIM, DEPER, grad_fn, data, placement=pl,
                      faults=faults, robust=spec), 3)
    _leaves_equal(sv, sm, msg=f"{spec}:")
    for a, b in zip(hv, hm):
        assert a["screened"] == b["screened"]


def test_check_cohort_enforced_at_build_time(data, x0):
    with pytest.raises(ValueError, match="trims"):
        make_round_fn(SIM, DEPER, grad_fn, data, robust="trimmed:0.45")
    with pytest.raises(ValueError, match="exceeds the cohort"):
        make_round_fn(SIM, DEPER, grad_fn, data, robust="bucket:8")


# ------------------------------------------------- drivers/store/compression

def test_robust_block_matches_host_loop(data, x0):
    """K=3 scan blocks under trimmed robust + collusion reproduce the
    host loop bitwise (the attack key is a pure function of the round
    rng, so the schedule is driver-independent)."""
    faults = make_faults("collude:0.25,clip:5.0")
    ref, _ = run_rounds(
        init_sim_state(SIM, DEPER, x0),
        make_round_fn(SIM, DEPER, grad_fn, data, faults=faults,
                      robust="trimmed:0.25"), 3)
    got, _ = run_blocks(
        init_sim_state(SIM, DEPER, x0),
        lambda size: make_block_fn(SIM, DEPER, grad_fn, data,
                                   block_size=size, faults=faults,
                                   robust="trimmed:0.25"), 3, 3)
    _leaves_equal(ref, got, msg="K=3:")


def test_robust_threads_through_virtual_store(data, x0):
    """The virtual-store round fn accepts the same robust spec and
    reproduces the dense engine bitwise (same cohort, same reducer)."""
    layout = make_layout("virtual:host")
    faults = make_faults("collude:0.25,clip:5.0")
    ref, _ = run_rounds(
        init_sim_state(SIM, DEPER, x0),
        make_round_fn(SIM, DEPER, grad_fn, data, faults=faults,
                      robust="trimmed:0.25", donate=False), 3)
    vrf = make_virtual_round_fn(SIM, DEPER, grad_fn, data, layout=layout,
                                faults=faults, robust="trimmed:0.25",
                                donate=False)
    state = init_sim_state(SIM, DEPER, x0, layout=layout)
    for _ in range(3):
        state, _ = vrf(state)
    _leaves_equal(ref, state, keys=("x",), msg="virtual:")


def test_robust_composes_with_ef_compression(data, x0):
    """EF-compressed uploads are robust-reduced POST-decompress: the run
    stays finite, the EF store stays finite, and the reducer sees the
    decompressed stack (trajectory differs from dense -- that is the
    compressor, not the reducer)."""
    comp = make_compressor("topk:0.25")
    faults = make_faults("collude:0.25,clip:5.0")
    state, hist = run_rounds(
        init_sim_state(SIM, DEPER, x0, compressor=comp),
        make_round_fn(SIM, DEPER, grad_fn, data, compressor=comp,
                      faults=faults, robust="trimmed:0.25"), 4)
    assert state_is_finite(state)
    for leaf in jax.tree.leaves(state["ef"]):
        assert np.all(np.isfinite(np.asarray(leaf)))


# ---------------------------------------------------------- acceptance run

def test_krum_recovers_clip_riding_collusion(x0):
    """THE acceptance run (bench row's robust_matrix, test-pinned):
    20% colluding lanes riding a 2.0 clip boundary over 24 rounds at the
    paper's cross-silo operating point.  The plain mean craters; Krum
    (keep 7 of 10) finishes within 2% of the clean run."""
    ds10 = make_federated_classification(n_clients=10, per_client=64,
                                         split="shards", seed=2)
    data10 = {k: jnp.asarray(v) for k, v in ds10.train.items()}
    test10 = {k: jnp.asarray(v) for k, v in ds10.test.items()}
    eval_fn = make_global_eval(apply_loss, test10)
    sim = SimConfig(n_clients=10, m_sampled=10, tau=5, batch_size=32,
                    seed=0)
    faults = make_faults("collude:0.2,clip:2.0")

    def run(faults_, robust_):
        s, _ = run_rounds(
            init_sim_state(sim, DEPER, x0),
            make_round_fn(sim, DEPER, grad_fn, data10, faults=faults_,
                          robust=robust_), 24)
        assert state_is_finite(s)
        return float(eval_fn(s)["test_acc"])

    clean = run(None, None)
    attacked = run(faults, None)
    defended = run(faults, "krum:0.3")
    # the attack is real: the plain mean measurably craters
    assert attacked <= clean - 0.10, (clean, attacked)
    # the defense is real: Krum recovers to within 2% of clean
    assert defended >= clean - 0.02, (clean, defended)


# ----------------------------------------------------- 4-device emulation

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.paper_models import MLP_MNIST
    from repro.core import (FedDeper, Scaffold, SimConfig, MeshPlacement,
                            init_sim_state, make_robust, make_round_fn,
                            run_rounds)
    from repro.data import make_federated_classification
    from repro.faults import make_faults
    from repro.launch.mesh import make_client_mesh
    from repro.models import classifier_loss, init_classifier

    assert jax.local_device_count() == 4

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(
            lambda p, b: classifier_loss(MLP_MNIST, p, b),
            has_aux=True)(p, mb)
        return l, g

    ds = make_federated_classification(n_clients=8, per_client=64,
                                       split="shards", seed=2)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    x0 = init_classifier(MLP_MNIST, jax.random.PRNGKey(11))
    sim = SimConfig(n_clients=8, m_sampled=4, tau=2, batch_size=16,
                    seed=5)
    pl = MeshPlacement(make_client_mesh())
    faults = make_faults("collude:0.25,clip:5.0")

    def count(jx, names):
        n = {}
        for eqn in jx.eqns:
            if eqn.primitive.name in names:
                n[eqn.primitive.name] = n.get(eqn.primitive.name, 0) + 1
            for v in eqn.params.values():
                sub = v if hasattr(v, "eqns") else getattr(v, "jaxpr",
                                                           None)
                if sub is not None:
                    for k, c in count(sub, names).items():
                        n[k] = n.get(k, 0) + c
        return n
    names = {"psum", "psum2", "all_gather", "all_to_all", "ppermute"}

    strat = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    for spec in ("trimmed:0.25", "krum:0.25", "bucket:4"):
        sv, _ = run_rounds(
            init_sim_state(sim, strat, x0),
            make_round_fn(sim, strat, grad_fn, data, faults=faults,
                          robust=spec), 3)
        sm, _ = run_rounds(
            init_sim_state(sim, strat, x0, placement=pl),
            make_round_fn(sim, strat, grad_fn, data, placement=pl,
                          faults=faults, robust=spec), 3)
        # a REAL 4-way gather: lane order must equal shard order for the
        # reducers to agree -- bitwise, no reassociation tolerance
        for key in ("x", "clients", "pms"):
            for a, b in zip(jax.tree.leaves(sv[key]),
                            jax.tree.leaves(sm[key])):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b),
                                              err_msg=f"{spec}:{key}")

    # budget on a real axis, both strategies: gather modes one
    # all_gather + one psum; bucket and none exactly one collective
    for s in (strat, Scaffold(eta=0.05)):
        st = init_sim_state(sim, s, x0, placement=pl)
        for spec, want in (("none", None), ("krum:0.25", True),
                           ("trimmed:0.25", True), ("bucket:4", False)):
            rf = make_round_fn(sim, s, grad_fn, data, placement=pl,
                               faults=faults, robust=spec, donate=False)
            c = count(jax.make_jaxpr(rf)(st).jaxpr, names)
            g = c.pop("all_gather", 0)
            p = sum(c.values())
            if want:
                assert (g, p) == (1, 1), (s.name, spec, c)
            else:
                assert (g, p) == (0, 1), (s.name, spec, c)

    print("ROBUST_4DEV_OK")
""")


def test_robust_4device_emulation():
    """4-way client axis: every gather/bucket mode matches vmap bitwise
    across a real multi-shard gather, and the per-mode collective budget
    holds for FedDeper and Scaffold."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True,
                         env=_SUBPROC_ENV, timeout=560)
    assert "ROBUST_4DEV_OK" in out.stdout, (out.stdout[-1000:],
                                            out.stderr[-3000:])


# --------------------------------------------------- ckpt config validation

def test_restore_rejects_mismatched_robust_config(tmp_path):
    """A checkpoint stamped robust='krum:0.3' refuses to resume a run
    requesting a different reducer (silently switching defenses
    mid-attack invalidates the trajectory); legacy checkpoints without
    the key restore unchecked."""
    import argparse
    from repro.checkpoint import save_checkpoint
    from repro.launch.train import _ckpt_tree, _restore_state

    state = {"x": {"w": jnp.ones(2)}, "clients": {}, "pms": {},
             "server": {}, "rng": jax.random.PRNGKey(0)}
    args = argparse.Namespace(ckpt_dir=str(tmp_path))
    save_checkpoint(str(tmp_path), 3, _ckpt_tree(state),
                    metadata={"robust": "krum:0.3"})
    with pytest.raises(SystemExit, match="robust='krum:0.3'"):
        _restore_state(state, args, expect={"robust": "trimmed:0.25"})
    start, _ = _restore_state(state, args, expect={"robust": "krum:0.3"})
    assert start == 3
    for f in tmp_path.iterdir():
        f.unlink()
    save_checkpoint(str(tmp_path), 5, _ckpt_tree(state))
    start, _ = _restore_state(state, args, expect={"robust": "median"})
    assert start == 5
