"""End-to-end behaviour tests: full FL training runs + launch machinery."""
import dataclasses
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from conftest import SUBPROC_ENV as _SUBPROC_ENV
from repro.configs import get_config
from repro.configs.paper_models import MLP_MNIST
from repro.core import (FedAvg, FedDeper, SimConfig, init_sim_state,
                        make_global_eval, make_personal_eval, make_round_fn,
                        run_rounds)
from repro.data import make_federated_classification
from repro.models import classifier_loss, init_classifier


def _task(n=8, seed=11):
    cfg = MLP_MNIST
    ds = make_federated_classification(n_clients=n, per_client=128,
                                       split="shards", noise=3.0, seed=seed)

    def apply_loss(p, b):
        return classifier_loss(cfg, p, b)

    def grad_fn(p, mb):
        (l, _), g = jax.value_and_grad(apply_loss, has_aux=True)(p, mb)
        return l, g

    return cfg, ds, apply_loss, grad_fn


def test_full_training_improves_and_personal_eval_runs():
    cfg, ds, apply_loss, grad_fn = _task()
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    test = {k: jnp.asarray(v) for k, v in ds.test.items()}
    personal = {k: jnp.asarray(v) for k, v in ds.personal_test.items()}
    sim = SimConfig(8, 4, 8, 32, seed=2)
    strat = FedDeper(eta=0.05, rho=0.03, lam=0.5)
    state = init_sim_state(sim, strat, init_classifier(cfg,
                                                       jax.random.PRNGKey(0)))
    rf = make_round_fn(sim, strat, grad_fn, data)
    ge = make_global_eval(apply_loss, test)
    pe = make_personal_eval(apply_loss, personal)
    acc0 = float(ge(state)["test_acc"])
    state, hist = run_rounds(state, rf, 25)
    accs = ge(state)
    paccs = pe(state)
    assert float(accs["test_acc"]) > max(0.6, acc0 + 0.2)
    # Thm 2 qualitative: personalized models orbit the global optimum
    assert float(paccs["pm_acc"]) > 0.5
    assert np.isfinite(float(paccs["pm_loss"]))


def test_feddeper_beats_fedavg_convergence_rate():
    """C3 at test scale: by a mid-training round, FedDeper's global train
    loss is below FedAvg's (same seeds, same sampling)."""
    cfg, ds, apply_loss, grad_fn = _task(seed=4)
    data = {k: jnp.asarray(v) for k, v in ds.train.items()}
    finals = {}
    for strat in (FedAvg(eta=0.05), FedDeper(eta=0.05, rho=0.03, lam=0.5)):
        sim = SimConfig(8, 4, 10, 32, seed=9)
        state = init_sim_state(sim, strat,
                               init_classifier(cfg, jax.random.PRNGKey(0)))
        rf = make_round_fn(sim, strat, grad_fn, data)
        state, hist = run_rounds(state, rf, 25)
        finals[strat.name] = float(np.mean(
            [h["local_loss"] for h in hist[-8:]]))
    assert finals["feddeper"] <= finals["fedavg"] + 0.02, finals


def test_step_spec_lowers_on_single_device_mesh():
    """The dry-run machinery (specs + shardings + jit.lower) works on the
    1-device test mesh with a reduced config -- the 512-device version
    only changes the mesh."""
    from repro.launch.mesh import make_smoke_mesh
    from repro.launch.specs import make_step_spec
    import repro.configs.base as cb

    mesh = make_smoke_mesh()
    cfg = get_config("llama3.2-3b").reduced()
    # shrink the input shape so CPU lowering is fast
    cb.INPUT_SHAPES["_tiny_train"] = cb.InputShape("_tiny_train", 64, 4,
                                                   "train")
    cb.INPUT_SHAPES["_tiny_decode"] = cb.InputShape("_tiny_decode", 64, 2,
                                                    "decode")
    try:
        spec = make_step_spec(cfg, "_tiny_train", mesh, tau=2)
        lowered = jax.jit(spec.fn,
                          in_shardings=spec.in_shardings).lower(*spec.args)
        assert lowered.compile() is not None
        spec = make_step_spec(cfg, "_tiny_decode", mesh)
        lowered = jax.jit(spec.fn,
                          in_shardings=spec.in_shardings).lower(*spec.args)
        assert lowered.compile() is not None
    finally:
        cb.INPUT_SHAPES.pop("_tiny_train")
        cb.INPUT_SHAPES.pop("_tiny_decode")


def test_collective_parser_on_synthetic_hlo():
    from repro.launch.hlo_analysis import parse_collectives
    hlo = """
  %ar = bf16[128,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag.1 = f32[64]{0} all-gather(%y), replica_groups=[2,8]<=[16]
  %nop = bf16[4]{0} add(%a, %b)
  %rs = bf16[32,32]{1,0} reduce-scatter(%z), replica_groups={{0,1}}
"""
    stats = parse_collectives(hlo)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1}
    ar = 128 * 1024 * 2 * 2 * 3 / 4  # 2(n-1)/n * bytes, n=4
    ag = 64 * 4 * 7 / 8              # (n-1)/n, n=8
    rs = 32 * 32 * 2 * 1             # (n-1), n=2
    np.testing.assert_allclose(stats.total_bytes, ar + ag + rs)


def test_train_cli_entrypoint():
    """The launch/train.py driver runs end-to-end (reduced, 3 rounds)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "llama3.2-3b", "--reduced", "--clients", "2", "--tau", "2",
         "--rounds", "3", "--batch", "2", "--seq", "32"],
        capture_output=True, text=True, env=_SUBPROC_ENV,
        cwd=".", timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    lines = [json.loads(l) for l in out.stdout.strip().splitlines()]
    assert lines[-1]["round"] == 3
    assert np.isfinite(lines[-1]["local_loss"])


def test_train_cli_async_entrypoint():
    """The buffered-async regime through the same CLI (tiny settings)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "llama3.2-3b", "--reduced", "--regime", "async", "--clients", "4",
         "--concurrent", "2", "--buffer", "2", "--delay", "3", "--tau", "2",
         "--rounds", "3", "--batch", "2", "--seq", "32",
         "--per-client", "8"],
        capture_output=True, text=True, env=_SUBPROC_ENV,
        cwd=".", timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    lines = [json.loads(l) for l in out.stdout.strip().splitlines()]
    assert lines[-1]["round"] == 3
    assert lines[-1]["version"] == 3
    assert np.isfinite(lines[-1]["local_loss"])
    assert lines[-1]["sim_time"] > 0


def test_train_cli_async_resume_restores_clock(tmp_path):
    """A resumed async run must continue the simulated clock and version
    instead of resetting them to zero (the checkpoint meta carries t and
    version; restore used to drop both)."""
    ckpt = str(tmp_path / "ck")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "llama3.2-3b", "--reduced", "--regime", "async", "--clients",
            "4", "--concurrent", "2", "--buffer", "2", "--delay", "3",
            "--tau", "2", "--batch", "2", "--seq", "32", "--per-client",
            "8", "--ckpt-dir", ckpt, "--ckpt-every", "2"]
    first = subprocess.run(args + ["--rounds", "2"], capture_output=True,
                           text=True, env=_SUBPROC_ENV, cwd=".",
                           timeout=560)
    assert first.returncode == 0, first.stderr[-2000:]
    l1 = [json.loads(l) for l in first.stdout.strip().splitlines()]
    resumed = subprocess.run(args + ["--rounds", "4"], capture_output=True,
                             text=True, env=_SUBPROC_ENV, cwd=".",
                             timeout=560)
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    assert "restored round 2" in resumed.stdout
    l2 = [json.loads(l) for l in resumed.stdout.strip().splitlines()
          if l.startswith("{")]
    assert [r["round"] for r in l2] == [3, 4]
    assert l2[0]["version"] == l1[-1]["version"] + 1
    assert l2[0]["sim_time"] >= l1[-1]["sim_time"]


def test_train_cli_rejects_bandwidth_outside_async():
    """--bandwidth prices the simulated async uplink queue; in the
    synchronous regimes it would silently do nothing, so the CLI fails
    fast."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "llama3.2-3b", "--reduced", "--placement", "vmap", "--clients",
         "2", "--tau", "2", "--rounds", "1", "--batch", "2", "--seq",
         "32", "--bandwidth", "1e6"],
        capture_output=True, text=True, env=_SUBPROC_ENV,
        cwd=".", timeout=560)
    assert out.returncode != 0
    assert "--regime async" in (out.stderr + out.stdout)
