"""Model-layer numerics: chunked attention vs oracle, MLA absorbed decode,
mLSTM chunkwise vs recurrent, Mamba decode vs scan, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.attention import chunked_attention, decode_attention


@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 32, None), (True, None, 30.0),
    (False, None, None)])
def test_chunked_attention_matches_oracle(causal, window, cap):
    B, S, H, K, D = 2, 96, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    pos = jnp.arange(S)
    out = chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                            causal=causal, window=window, cap=cap,
                            q_chunk=32, kv_chunk=48)
    r = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_decode_attention_matches_full():
    B, L, H, K, D = 2, 24, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kc = jax.random.normal(ks[1], (B, L, K, D))
    vc = jax.random.normal(ks[2], (B, L, K, D))
    valid = 17
    out = decode_attention(q, kc, vc, valid_len=valid)
    # oracle: softmax over the first `valid` slots only
    G = H // K
    s = jnp.einsum("bqkgd,bjkd->bkgqj",
                   q.reshape(B, 1, K, G, D), kc) * (D ** -0.5)
    s = jnp.where(jnp.arange(L)[None, None, None, None] < valid, s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    r = jnp.einsum("bkgqj,bjkd->bqkgd", p, vc).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_sliding_window_ring_buffer_roll():
    """Prefill S>window stores the last `window` keys at slots g mod w."""
    from repro.configs import get_config
    from repro.models.attention import apply_gqa, gqa_cache_spec
    from repro.configs.base import LayerSpec
    import dataclasses
    cfg = dataclasses.replace(get_config("gemma2-9b").reduced(),
                              qkv_bias=False)
    spec = LayerSpec(kind="attn", ffn="dense", window=8)
    from repro.models.attention import init_gqa
    params = init_gqa(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S, w = 1, 20, 8
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.1
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cache0 = gqa_cache_spec(cfg, spec, B, w, jnp.float32)
    out_pre, cache = apply_gqa(cfg, spec, params, x, positions=positions,
                               mode="prefill", cache=cache0)
    # decode the next token; compare against full recompute over S+1
    x_new = jax.random.normal(jax.random.PRNGKey(3), (B, 1, cfg.d_model)) \
        * 0.1
    out_dec, _ = apply_gqa(cfg, spec, params, x_new,
                           positions=jnp.full((B, 1), S), mode="decode",
                           cache=cache, pos=jnp.int32(S))
    x_full = jnp.concatenate([x, x_new], axis=1)
    pos_full = jnp.broadcast_to(jnp.arange(S + 1), (B, S + 1))
    out_full, _ = apply_gqa(cfg, spec, params, x_full, positions=pos_full,
                            mode="train")
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_full[:, -1]), rtol=2e-4,
                               atol=2e-4)


def test_mla_absorbed_decode_matches_expanded():
    """MLA decode (absorbed, latent-space scores) == expanded-form attention
    over the same tokens."""
    from repro.configs import get_config
    from repro.models.attention import apply_mla, mla_cache_spec
    from repro.configs.base import LayerSpec
    cfg = get_config("deepseek-v3-671b").reduced()
    spec = LayerSpec(kind="attn", ffn="moe")
    from repro.models.attention import init_mla
    params = init_mla(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 1, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.1
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    out_train, _ = apply_mla(cfg, spec, params, x, positions=positions,
                             mode="train")
    cache = mla_cache_spec(cfg, B, S, jnp.float32)
    _, cache = apply_mla(cfg, spec, params, x[:, :S - 1],
                         positions=positions[:, :S - 1], mode="prefill",
                         cache=cache)
    out_dec, _ = apply_mla(cfg, spec, params, x[:, S - 1:],
                           positions=positions[:, S - 1:], mode="decode",
                           cache=cache, pos=jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_train[:, -1]), rtol=3e-4,
                               atol=3e-4)


def test_mlstm_chunkwise_matches_recurrent():
    from repro.models.xlstm import mlstm_chunkwise, mlstm_recurrent
    B, S, H, dh = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh)) * (dh ** -0.5)
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ig = jax.random.normal(ks[3], (B, S, H))
    lf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, S, H)) - 1.0)
    state = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
             jnp.full((B, H), -1e30))
    h_rec, st_rec = mlstm_recurrent(q, k, v, ig, lf, state)
    h_chk, st_chk = mlstm_chunkwise(q, k, v, ig, lf,
                                    tuple(jnp.asarray(s) for s in state),
                                    chunk=16)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_rec),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(st_rec, st_chk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-3,
                                   atol=2e-3)


def test_mamba_decode_matches_scan():
    """Step-by-step decode must reproduce the associative-scan forward."""
    from repro.configs import get_config
    from repro.models.ssm import apply_mamba, init_mamba, mamba_cache_spec
    cfg = get_config("jamba-v0.1-52b").reduced()
    params = init_mamba(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    out_scan, _ = apply_mamba(cfg, params, x, mode="train")
    cache = mamba_cache_spec(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = apply_mamba(cfg, params, x[:, t:t + 1], mode="decode",
                               cache=cache)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_step), np.asarray(out_scan),
                               rtol=2e-4, atol=2e-4)


def test_moe_dispatch_matches_dense_oracle():
    """With ample capacity, scatter-dispatch MoE == per-token dense mix."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.moe import apply_moe, init_moe
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              capacity_factor=8.0)
    params = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    out, aux = apply_moe(cfg, params, x)
    assert float(aux.dropped_frac) == 0.0

    # oracle: dense per-token expert mixture over the same top-k routing
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / gates.sum(-1, keepdims=True)
    hs = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["we_gate"])) * \
        jnp.einsum("td,edf->tef", xt, params["we_up"])
    ys = jnp.einsum("tef,efd->ted", hs, params["we_down"])
    want = jnp.einsum("tk,tkd->td", gates,
                      jnp.take_along_axis(ys, eidx[..., None], 1))
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
